"""Tests for the coverage-guided fault-injection fuzzer (the PR-7 tentpole).

Pure-unit halves (trajectory model, coverage DB, derived universe, mutator
determinism, minimizer logic) run without touching JAX; the integration
half drives real trajectories through the runner and checks the oracles,
coverage extraction and bit-for-bit replay on the live serving stack.
"""
import json

import numpy as np
import pytest

from repro.core.errors import ErrorCode
from repro.core.recovery import RecoveryPolicy
from repro.fuzz import (
    CoverageDB,
    FaultMutator,
    FuzzCampaign,
    Op,
    Trajectory,
    action_ladder,
    load_entry,
    minimize,
    reachable_cells,
    run_trajectory,
    write_entry,
)
from repro.fuzz.coverage import PAGED_ENGINES
from repro.fuzz.trajectory import (
    ENGINES,
    GROUP_ENGINE,
    MULTIHOST_ENGINE,
    SINGLE_ENGINES,
)

NAN = ErrorCode.NONFINITE_LOSS


# ------------------------------------------------------------- trajectory model
class TestTrajectory:
    def test_json_round_trip(self):
        t = Trajectory(seed=9, engine="overlap_paged", n_requests=4,
                       prompt_len=5, max_new=8, max_request_retries=1,
                       ops=[Op("word", cycle=2, slot=1, step=3,
                               code=int(NAN)),
                            Op("page_table", cycle=4, slot=0)],
                       note="test")
        assert Trajectory.loads(t.dumps()) == t
        assert Trajectory.from_json(json.loads(t.dumps())) == t

    def test_prompts_are_derived_not_stored(self):
        t = Trajectory(seed=0, engine="overlap", n_requests=2, prompt_len=3)
        assert t.prompts() == [(5, 6, 7), (6, 7, 8)]
        assert "prompt" not in json.dumps(t.to_json())[:-1].replace(
            '"prompt_len"', "")

    def test_kill_only_on_group_engine(self):
        with pytest.raises(ValueError, match="kill"):
            Trajectory(seed=0, engine="overlap",
                       ops=[Op("kill", cycle=1, slot=0)])
        with pytest.raises(ValueError, match="word"):
            Trajectory(seed=0, engine=GROUP_ENGINE,
                       ops=[Op("word", cycle=1, code=int(NAN))])

    def test_bad_ops_rejected(self):
        with pytest.raises(ValueError, match="nonzero"):
            Op("word", cycle=1, slot=0, code=0)
        with pytest.raises(ValueError, match="unknown op"):
            Op("wrod", cycle=1)
        with pytest.raises(ValueError, match="unknown engine"):
            Trajectory(seed=0, engine="stepwse")


# ------------------------------------------------------------------- coverage
class TestCoverage:
    def test_universe_is_derived_from_the_policy(self):
        cells = reachable_cells()
        assert len(cells) > 100
        # every soft code ladders skip→restore→rollback on every engine
        for engine in SINGLE_ENGINES:
            assert ("NONFINITE_LOSS", "skip_batch", engine) in cells
            assert ("NONFINITE_LOSS", "rollback", engine) in cells
        # engine-specific lanes
        for engine in SINGLE_ENGINES:
            assert (("PAGE_FAULT", "page_reclaim", engine) in cells) == (
                engine in PAGED_ENGINES)
        assert ("COMM_CORRUPTED", "shrink", GROUP_ENGINE) in cells
        assert ("RANK_FAILED", "reroute", GROUP_ENGINE) in cells
        # multihost (real OS process) lanes: heartbeat-detected eviction and
        # the SIGSTOP suspected-then-cleared false-positive guard
        assert ("RANK_FAILED", "evict", MULTIHOST_ENGINE) in cells
        assert ("STRAGGLER", "resume", MULTIHOST_ENGINE) in cells
        # hard/attribution-only lanes never appear as injectable cells
        assert not any(c[0] == "DRAFT_REJECT" for c in cells)
        assert not any(c[0] == "RANK_FAILED"
                       and c[2] not in (GROUP_ENGINE, MULTIHOST_ENGINE)
                       for c in cells)

    def test_action_ladder_replays_the_real_policy(self):
        ladder = action_ladder(NAN, depth=5)
        assert ladder == ["skip_batch", "restore_good", "restore_good",
                          "rollback", "rollback"]
        assert action_ladder(ErrorCode.DIVERGENCE)[0] == "reset_optimizer"

    def test_db_records_and_persists(self, tmp_path):
        path = str(tmp_path / "cov.json")
        db = CoverageDB(path)
        cell = ("NONFINITE_LOSS", "skip_batch", "overlap")
        assert db.record([cell]) == [cell]          # new
        assert db.record([cell]) == []              # already covered
        assert db.covered(cell)
        universe = [cell, ("USER", "skip_batch", "overlap")]
        assert db.fraction(universe) == 0.5
        assert db.uncovered(universe) == [("USER", "skip_batch", "overlap")]
        db.save()
        again = CoverageDB(path)
        assert again.cells() == {cell}
        rep = again.report(universe)
        assert rep["covered"] == 1 and rep["universe"] == 2

    def test_report_flags_cells_outside_the_universe(self):
        db = CoverageDB()
        db.record([("USER", "weird_action", "overlap")])
        rep = db.report([("USER", "skip_batch", "overlap")])
        assert rep["extra"] == ["USER|weird_action|overlap"]


# -------------------------------------------------------------------- mutator
class TestMutator:
    def test_proposals_replay_from_seed_and_index(self):
        a = FaultMutator(3, CoverageDB()).propose(7)
        b = FaultMutator(3, CoverageDB()).propose(7)
        assert a == b
        assert FaultMutator(4, CoverageDB()).propose(7) != a

    def test_targeted_mode_attacks_uncovered_cells(self):
        db = CoverageDB()
        mut = FaultMutator(0, db, engines=("overlap",), targeted_bias=1.0)
        traj = mut.propose(0)
        assert traj.engine == "overlap"
        assert traj.note.startswith("targeted:")
        assert traj.ops                      # ladder prefix scheduled
        # covering the whole universe flips the mutator to random/mutate mode
        db.record(mut.universe)
        assert not db.uncovered(mut.universe)
        assert mut.propose(1).note.startswith(("random", "mutant"))

    def test_group_trajectories_carry_exactly_one_kill(self):
        mut = FaultMutator(1, CoverageDB(), engines=(GROUP_ENGINE,))
        seen = set()
        for i in range(12):
            traj = mut.propose(i)
            assert traj.engine == GROUP_ENGINE
            kinds = [op.op for op in traj.ops]
            assert kinds.count("kill") == 1
            assert kinds.count("restart") <= 1
            assert kinds.count("rejoin") <= 1
            assert set(kinds) <= {"kill", "restart", "rejoin"}
            # a restart lands after the kill: the crash must catch the
            # shrunken fleet mid-replay of the re-routed backlog
            kill = next(o for o in traj.ops if o.op == "kill")
            for op in traj.ops:
                if op.op == "restart":
                    assert op.cycle >= kill.cycle + 3
            seen.update(kinds)
        # across a dozen seeded proposals every elastic lane gets exercised
        assert seen == {"kill", "restart", "rejoin"}

    def test_mutants_stay_valid(self):
        mut = FaultMutator(2, CoverageDB())
        rng = np.random.default_rng(0)
        parent = mut.propose(0)
        for _ in range(20):
            parent = mut.mutate(parent, rng)   # __post_init__ validates
        assert parent.engine == mut.propose(0).engine

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engines"):
            FaultMutator(0, CoverageDB(), engines=("warp",))


# ------------------------------------------------------------------ minimizer
class TestMinimizer:
    def test_greedy_drop_keeps_only_the_culprit(self, monkeypatch):
        culprit = Op("word", cycle=5, slot=1, step=2, code=int(NAN))
        noise = [Op("word", cycle=c, slot=0, step=0,
                    code=int(ErrorCode.USER)) for c in (1, 2, 3)]

        class FakeResult:
            def __init__(self, failed):
                self.failed = failed
                self.violations = ["boom"] if failed else []

        def fake_run(traj):
            return FakeResult(culprit in traj.ops)

        import repro.fuzz.campaign as campaign
        monkeypatch.setattr(campaign, "run_trajectory", fake_run)
        traj = Trajectory(seed=0, engine="overlap", n_requests=4,
                          prompt_len=7, max_new=12,
                          ops=noise[:2] + [culprit] + noise[2:])
        small, res = minimize(traj)
        assert small.ops == (culprit,)
        assert res.failed
        # load shrinking kicked in too
        assert small.n_requests == 2
        assert small.max_new == 5

    def test_passing_trajectory_comes_back_unchanged(self, monkeypatch):
        import repro.fuzz.campaign as campaign

        class Passing:
            failed = False
            violations = []

        monkeypatch.setattr(campaign, "run_trajectory",
                            lambda t: Passing())
        traj = Trajectory(seed=0, engine="overlap",
                          ops=[Op("poison", cycle=1)])
        small, res = minimize(traj)
        assert small == traj and not res.failed


# --------------------------------------------------------------------- corpus
class TestCorpusIO:
    def test_entry_round_trip(self, tmp_path):
        traj = Trajectory(seed=1, engine="spec", n_requests=2,
                          ops=[Op("word", cycle=2, code=int(NAN))])
        path = write_entry(str(tmp_path), "e1", traj, status="seed",
                           digest="abcd", cells=[("NONFINITE_LOSS",
                                                  "skip_batch", "spec")])
        entry = load_entry(path)
        assert entry["trajectory"] == traj
        assert entry["status"] == "seed"
        assert entry["digest"] == "abcd"
        assert entry["cells"] == ["NONFINITE_LOSS|skip_batch|spec"]


# -------------------------------------------------- integration (real stack)
class TestRunnerIntegration:
    def test_clean_run_passes_every_oracle(self):
        res = run_trajectory(Trajectory(seed=0, engine="overlap",
                                        n_requests=2, prompt_len=3,
                                        max_new=5))
        assert res.violations == []
        assert res.cells == set()

    def test_injected_ladder_covers_cells_and_stays_bit_exact(self):
        traj = Trajectory(
            seed=1, engine="overlap", n_requests=4, prompt_len=5, max_new=12,
            max_request_retries=6,
            ops=[Op("word", cycle=2 + k, slot=k % 2, step=1, code=int(NAN))
                 for k in range(4)])
        res = run_trajectory(traj)
        assert res.violations == []      # bit-exact + no drops despite 4 faults
        assert {("NONFINITE_LOSS", "skip_batch", "overlap"),
                ("NONFINITE_LOSS", "restore_good", "overlap"),
                ("NONFINITE_LOSS", "rollback", "overlap")} <= res.cells

    def test_replay_is_bit_for_bit(self):
        traj = Trajectory(seed=2, engine="overlap", n_requests=3,
                          prompt_len=5, max_new=8,
                          ops=[Op("word", cycle=2, slot=0, step=1,
                                  code=int(NAN)),
                               Op("preempt", cycle=3, slot=1)])
        a, b = run_trajectory(traj), run_trajectory(traj)
        assert a.digest() == b.digest()
        assert a.violations == b.violations == []
        assert a.cells == b.cells

    def test_non_injectable_word_is_rejected_by_the_replica(self):
        # the injector hook itself enforces the injectable mask: a trajectory
        # cannot even express this (Op validates at run), so go through a
        # hand-rolled injector to pin the replica-side guard
        from repro.fuzz.runner import get_kit
        from repro.serve.config import EngineConfig
        from repro.serve.queue import Request
        from repro.serve.replica import Replica

        kit = get_kit("overlap")
        rep = Replica(kit.cfg, params=kit.params,
                      config=EngineConfig(num_slots=2, max_len=32, window=4,
                                          overlap=True),
                      decode_fn=kit.decode_fn, prefill_fn=kit.prefill_fn,
                      window_fn=kit.window_fn,
                      fault_injector=lambda i, shape: np.full(
                          shape, int(ErrorCode.DRAFT_REJECT), np.uint32))
        assert rep.submit(Request(id=0, prompt=(5, 6, 7),
                                  max_new_tokens=4)) is None
        with pytest.raises(ValueError, match="non-injectable"):
            rep.run()

    def test_campaign_smoke_covers_and_replays(self, tmp_path):
        db = CoverageDB(str(tmp_path / "cov.json"))
        camp = FuzzCampaign(seed=0, db=db, corpus_dir=str(tmp_path / "c"),
                            engines=("overlap",))
        rep = camp.run(3)
        assert rep.ran == 3
        assert not [c for c in rep.counterexamples if not c.get("flaky")]
        assert rep.coverage["covered"] > 0
        paths = camp.promote_seeds(2)
        for p in paths:
            entry = load_entry(p)
            res = run_trajectory(entry["trajectory"])
            assert res.violations == []
            assert res.digest() == entry["digest"]
