"""Substrate tests: checkpointer, buddy store, data pipeline, optimizer,
gradient compression (+ hypothesis properties)."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                    "(pip install repro[test])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.checkpoint import BuddyStore, Checkpointer
from repro.data.pipeline import DataIterator, PipelineConfig, make_batch
from repro.optim import AdamWConfig, adamw_update, init_opt_state, schedule
from repro.optim.compress import (
    CompressionConfig,
    compress_with_feedback,
    init_residuals,
    quantize_int8,
    dequantize_int8,
)


# ------------------------------------------------------------------ checkpoint
def _toy_state():
    return {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,)),
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    st_ = _toy_state()
    ck.save(7, st_, blocking=True)
    got = ck.restore_latest(like=st_)
    assert got is not None
    step, restored = got
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(st_)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st_ = _toy_state()
    for s in (1, 2, 3, 4):
        ck.save(s, st_, blocking=True)
    assert ck.list_steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path)
    st_ = _toy_state()
    ck.save(5, st_, blocking=True)
    # corrupt one leaf on disk
    leaf = next((tmp_path / "step-0000000005").glob("leaf-*.npy"))
    arr = np.load(leaf)
    arr.reshape(-1)[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        ck.restore(5, like=st_)
    # restore_latest skips the corrupt one → nothing else → None
    assert ck.restore_latest(like=st_) is None


def test_checkpoint_async_does_not_block(tmp_path):
    ck = Checkpointer(tmp_path)
    st_ = {"w": jnp.zeros((512, 512))}
    ck.save(1, st_)          # returns immediately
    ck.wait()
    assert ck.list_steps() == [1]
    assert ck.last_error is None


def test_buddy_store_cycle():
    b = BuddyStore(4)
    assert b.buddy_of(3) == 0
    b.push(2, 10, {"w": jnp.ones((3,))})
    step, shard = b.recover(2)
    assert step == 10
    np.testing.assert_array_equal(shard["w"], np.ones((3,)))
    b.drop(2)
    assert b.recover(2) is None


# ------------------------------------------------------------------- pipeline
def test_pipeline_determinism_and_resume():
    cfg = PipelineConfig(vocab_size=128, seq_len=16, batch_size=4, seed=3)
    it1 = DataIterator(cfg)
    batches = [next(it1) for _ in range(5)]
    # resume from checkpointed cursor reproduces the stream exactly
    it2 = DataIterator(cfg)
    it2.load_state_dict({"step": 3, "seed": 3, "shard": 0, "num_shards": 1})
    b3 = next(it2)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(batches[3]["tokens"]))


def test_pipeline_shards_differ():
    cfg = PipelineConfig(vocab_size=128, seq_len=16, batch_size=4, seed=3,
                         num_shards=2, shard=0)
    a = make_batch(cfg, 0)
    b = make_batch(dataclasses.replace(cfg, shard=1), 0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_pipeline_tokens_in_range():
    cfg = PipelineConfig(vocab_size=97, seq_len=33, batch_size=3, seed=11)
    for step in (0, 7, 1000):
        b = make_batch(cfg, step)
        t = np.asarray(b["tokens"])
        assert t.min() >= 0 and t.max() < 97


# ------------------------------------------------------------------ optimizer
def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    step = jnp.int32(0)
    for i in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, params, grads, opt, step + i)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


# ----------------------------------------------------------------- compression
def test_int8_roundtrip_bounded_error():
    x = jnp.linspace(-3, 3, 1000)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["int8", "topk"]))
def test_error_feedback_is_lossless_over_time(seed, codec):
    """Property: with error feedback, Σ(sent) + residual == Σ(grads) exactly —
    nothing is ever silently lost (the residual carries it forward)."""
    rng = np.random.default_rng(seed)
    cfg = CompressionConfig(codec=codec, topk_fraction=0.25)
    g_total = np.zeros((32,), np.float64)
    sent_total = np.zeros((32,), np.float64)
    residual = jnp.zeros((32,), jnp.float32)
    for _ in range(5):
        g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        sent, residual = compress_with_feedback(g, residual, cfg)
        g_total += np.asarray(g, np.float64)
        sent_total += np.asarray(sent, np.float64)
    gap = np.abs(g_total - (sent_total + np.asarray(residual, np.float64)))
    assert gap.max() < 1e-4


def test_topk_sparsity():
    cfg = CompressionConfig(codec="topk", topk_fraction=0.1,
                            error_feedback=False)
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    sent, _ = compress_with_feedback(g, jnp.zeros_like(g), cfg)
    nz = int(jnp.sum(sent != 0))
    assert nz <= 110  # ~10% (ties allowed)
