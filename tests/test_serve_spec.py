"""Speculative decode windows (`Replica(speculate=True)`): token-bit-exact
draft-and-verify vs the overlap engine on steady, faulted and paged traffic;
per-slot variable commit (EOS inside an accepted draft run, deadline expiry
mid-window); the attribution-only DRAFT_REJECT lane never triggering
recovery; LFLR after a real fault mid-speculation committing no stale draft
tokens; acceptance-rate metrics; and the host-sync budget staying O(steps/K).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import count_syncs

from repro.configs import smoke_config
from repro.core.device_channel import DeviceFuture
from repro.core.errors import ATTRIBUTION_ONLY, ErrorCode
from repro.launch.steps import PerfOptions, make_speculative_decode_window
from repro.models import build_model
from repro.serve import EXPIRED, OK, EngineConfig, Replica, Request, ServeGroup
from repro.serve.replica import make_window_enum_fn

MAX_LEN = 64
D = 3           # draft_len for the suite
K = 8


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _replica(env, *, speculate, **kw):
    cfg, params = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", MAX_LEN)
    conf.setdefault("max_request_retries", 6)
    return Replica(cfg, params=params,
                   config=EngineConfig(window=K, overlap=True,
                                       speculate=speculate, draft_len=D,
                                       draft_layers=1, **conf), **kw)


def _requests(n, max_new=16, prompt_len=9):
    return [Request(id=i, prompt=tuple(5 + i + j for j in range(prompt_len)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_all(rep, reqs, inject_first_eligible=False):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps, injected = {}, 0, 0
    while not rep.idle():
        if inject_first_eligible and not injected:
            # poison a *decoding* lane (a fresh chunk lane's reset would
            # silently wipe the injection before any window consumes it)
            eligible = [i for i in rep.sched.active_slots()
                        if rep.sched.slots[i].pending is None]
            if eligible and rep.inject_state_fault(eligible[0]) is not None:
                injected += 1
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 2000
    if inject_first_eligible:
        assert injected == 1, "fault injection never found a decoding lane"
    return out


# ------------------------------------------------------------- bit-exactness
def test_spec_bit_exact_steady(env):
    """Every emitted token is a full-model argmax, so draft-and-verify must
    be invisible in the stream — including backfill chains (5 requests over
    2 slots)."""
    base = _serve_all(_replica(env, speculate=False), _requests(5))
    rep = _replica(env, speculate=True)
    got = _serve_all(rep, _requests(5))
    assert sorted(got) == sorted(base)
    for i in base:
        assert got[i].status == OK
        assert got[i].tokens == base[i].tokens, i
    assert rep.metrics.host_stalls == 0
    assert rep.metrics.windows > 0


def test_spec_bit_exact_faulted_lflr(env):
    """A real fault mid-speculation recovers via LFLR bit-exactly: the
    poisoned lane's stream is identical to the overlap engine under the same
    injection, and the fault surfaces as a real (non-DRAFT_REJECT) class."""
    base = _serve_all(_replica(env, speculate=False), _requests(5),
                      inject_first_eligible=True)
    rep = _replica(env, speculate=True)
    got = _serve_all(rep, _requests(5), inject_first_eligible=True)
    for i in base:
        assert got[i].status == OK
        assert got[i].tokens == base[i].tokens, i
    counts = rep.metrics.fault_counts()
    assert counts, "injected fault was never detected"
    assert "DRAFT_REJECT" not in counts


def test_spec_paged_bit_exact(env):
    """Speculation composes with the paged KV pool: same stream, ledger
    consistent, steady and faulted."""
    for inject in (False, True):
        base = _serve_all(_replica(env, speculate=False), _requests(5),
                          inject_first_eligible=inject)
        rep = _replica(env, speculate=True, paged=True, page_size=16)
        got = _serve_all(rep, _requests(5), inject_first_eligible=inject)
        for i in base:
            assert got[i].tokens == base[i].tokens, (inject, i)
        rep.alloc.check()


# ------------------------------------------------------- variable-commit path
def test_eos_inside_accepted_draft_run(env):
    """A request whose EOS lands *inside* an accepted draft run must stop at
    exactly the same token as the plain engine (commit_block checks token by
    token), with the trailing accepts discarded, not committed."""
    cfg, params = env
    # find an eos token that actually appears mid-stream in the clean run
    probe = _serve_all(_replica(env, speculate=False), _requests(2, max_new=24))
    stream = probe[0].tokens
    eos = stream[min(5, len(stream) - 2)]

    def serve(speculate):
        rep = _replica(env, speculate=speculate, eos_id=int(eos))
        return rep, _serve_all(rep, _requests(2, max_new=24))

    _, base = serve(False)
    rep, got = serve(True)
    for i in base:
        assert got[i].tokens == base[i].tokens, i
        assert got[i].status == base[i].status == OK
    # at least one lane must actually have stopped early on EOS
    assert any(len(r.tokens) < 24 for r in got.values())
    assert rep.metrics.discarded_tokens > 0


def test_deadline_expiry_mid_window(env):
    """A deadline passing mid-window evicts the lane at the window boundary;
    its already-emitted block is discarded wholesale, co-slot lanes are
    unaffected and the expired request is answered EXPIRED."""
    t = {"now": 0.0}
    rep = _replica(env, speculate=True, clock=lambda: t["now"])
    reqs = _requests(2, max_new=40)
    doomed = Request(id=99, prompt=(7, 8, 9), max_new_tokens=40, deadline=2.0)
    for r in [doomed] + reqs:      # doomed admitted first: it dies in a slot
        assert rep.submit(r) is None
    out, steps = {}, 0
    while not rep.idle():
        t["now"] += 1.0            # two cycles in, the deadline has passed
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 2000
    assert out[99].status == EXPIRED
    assert "mid-decode" in out[99].detail   # evicted from a live lane, not
    assert len(out[99].tokens) < 40         # the queue — block discarded
    assert out[0].status == OK and out[1].status == OK
    assert len(out[0].tokens) == 40 and len(out[1].tokens) == 40


def test_variable_commit_accounting(env):
    """Committed tokens must equal the sum of response streams, and the
    window ledger must balance: every emitted token is either committed or
    discarded, never duplicated. tokens_per_step counts per *dispatched*
    step (all slots), so the speculation signal is beating the plain engine
    at the SAME slot count on the same traffic — not merely exceeding 1."""
    plain = _replica(env, speculate=False)
    _serve_all(plain, _requests(4, max_new=12))
    rep = _replica(env, speculate=True)
    got = _serve_all(rep, _requests(4, max_new=12))
    m = rep.metrics
    assert m.decode_tokens == sum(len(r.tokens) for r in got.values())
    assert m.decode_steps == m.windows * K
    assert m.discarded_tokens >= 0
    assert m.tokens_per_step() > plain.metrics.tokens_per_step()


# ------------------------------------------------ DRAFT_REJECT attribution
def test_draft_reject_is_masked_from_fault_word():
    """A window whose only events are speculation misses must wait() clean:
    the enum strips DRAFT_REJECT from the combined word and the table, while
    the history keeps it for attribution."""
    enum = make_window_enum_fn(2, int(ErrorCode.DRAFT_REJECT))
    hist = np.zeros((K, 2), np.uint32)
    hist[3, 1] = int(ErrorCode.DRAFT_REJECT)
    combined, count, table, out_hist = enum(jnp.asarray(hist),
                                            jnp.ones((2,), jnp.uint32))
    assert int(combined) == 0 and int(count) == 0
    assert int(np.asarray(out_hist)[3, 1]) == int(ErrorCode.DRAFT_REJECT)
    fut = DeviceFuture(outputs="ok", word=combined, count=count, table=table,
                       history=out_hist)
    assert fut.wait() == "ok"          # never raises: attribution only
    # fault_steps with the mask sees a clean window; without it, the miss
    # is attributable to its exact (step, slot)
    assert list(fut.fault_steps(ignore=int(ATTRIBUTION_ONLY))) == [-1, -1]
    assert list(fut.fault_steps()) == [-1, 3]
    codes = fut.fault_codes()
    assert int(codes[1]) == int(ErrorCode.DRAFT_REJECT)


def test_draft_reject_does_not_truncate_clean_prefix():
    """A real fault behind rejected drafts: the committable prefix must run
    up to the *fault* step, not stop at the first speculation miss."""
    enum = make_window_enum_fn(1, int(ErrorCode.DRAFT_REJECT))
    hist = np.zeros((K, 1), np.uint32)
    hist[1, 0] = int(ErrorCode.DRAFT_REJECT)
    hist[5, 0] = int(ErrorCode.NONFINITE_LOSS) | int(ErrorCode.DRAFT_REJECT)
    combined, count, table, out_hist = enum(jnp.asarray(hist),
                                            jnp.ones((1,), jnp.uint32))
    assert int(combined) == int(ErrorCode.NONFINITE_LOSS)
    fut = DeviceFuture(outputs=None, word=combined, count=count, table=table,
                       history=out_hist)
    steps = fut.fault_steps(ignore=int(ErrorCode.DRAFT_REJECT))
    assert list(steps) == [5]
    codes = fut.fault_codes(ignore=int(ErrorCode.DRAFT_REJECT))
    assert int(codes[0]) == int(ErrorCode.NONFINITE_LOSS)


def test_spec_steady_run_never_recovers(env):
    """Steady speculative traffic must not consume retries or record faults
    — rejected drafts are expected events, not recoverable errors."""
    rep = _replica(env, speculate=True)
    got = _serve_all(rep, _requests(4, max_new=16))
    assert rep.metrics.faults == []
    assert sum(r.retries for r in got.values()) == 0


def test_real_fault_commits_no_stale_draft_tokens(env):
    """Tokens from the faulted step onward never commit: after LFLR the
    replayed stream is the deterministic greedy one, so the total stream is
    exactly the clean stream — a stale draft token would break equality."""
    clean = _serve_all(_replica(env, speculate=False), _requests(3))
    rep = _replica(env, speculate=True)
    got = _serve_all(rep, _requests(3), inject_first_eligible=True)
    for i in clean:
        assert got[i].tokens == clean[i].tokens, i


# ------------------------------------------------------------------- metrics
def test_acceptance_rate_metrics(env):
    rep = _replica(env, speculate=True)
    _serve_all(rep, _requests(4, max_new=16))
    m = rep.metrics
    assert m.draft_tokens > 0
    assert 0 <= m.accepted_draft_tokens <= m.draft_tokens
    assert 0.0 < m.acceptance_rate() <= 1.0
    assert m.acceptance_rate() == m.accepted_draft_tokens / m.draft_tokens
    per_slot = m.acceptance_rate_per_slot()
    assert per_slot and set(per_slot) <= {0, 1}
    assert all(0.0 <= v <= 1.0 for v in per_slot.values())
    # the global counters must equal the per-slot cells they were recorded
    # from — an independent reconstruction, not the summary's own formula
    cells = m._spec_per_slot
    assert m.draft_tokens == sum(d for d, _ in cells.values())
    assert m.accepted_draft_tokens == sum(a for _, a in cells.values())
    # accepted drafts can never exceed what a window can emit beyond its
    # forced rows: every commit this run came through windows, so committed
    # tokens bound accepted drafts from above
    assert m.accepted_draft_tokens <= m.decode_tokens + m.discarded_tokens
    s = m.summary()
    for key in ("draft_tokens", "accepted_draft_tokens",
                "rejected_draft_tokens", "acceptance_rate",
                "acceptance_rate_per_slot", "tokens_per_step"):
        assert key in s
    assert s["rejected_draft_tokens"] == (m.draft_tokens
                                          - m.accepted_draft_tokens)


# ---------------------------------------------------------- host-sync budget
def test_host_sync_budget(env, monkeypatch):
    """Speculation adds no per-token host traffic: the accepted counts ride
    the existing one-readback-per-window (word + token/count block), so syncs
    stay O(steps / K) — NOT O(tokens)."""

    def run():
        rep = _replica(env, speculate=True)
        return rep, _serve_all(rep, _requests(6, max_new=16))

    run()                                   # warm compiles
    syncs, (rep, out) = count_syncs(monkeypatch, run)
    assert all(r.status == OK for r in out.values())
    m = rep.metrics
    assert m.prefills == 0 and m.host_stalls == 0
    assert syncs <= 2 * m.windows + 4, (syncs, m.windows)
    # and the window count itself reflects multi-token commits: far fewer
    # windows than committed tokens / K
    assert m.windows * K < m.decode_tokens * 0.9


# ------------------------------------------------------------ configuration
def test_spec_validation(env):
    cfg, params = env
    with pytest.raises(ValueError, match="window"):
        Replica(cfg, params=params,
                config=EngineConfig(speculate=True, window=0))
    with pytest.raises(ValueError, match="overlap"):
        Replica(cfg, params=params,
                config=EngineConfig(speculate=True, window=8, overlap=False))
    with pytest.raises(ValueError, match="full-attention"):
        make_speculative_decode_window(smoke_config("recurrentgemma-2b"),
                                       window=8, draft_len=2, draft_layers=1)
    with pytest.raises(ValueError, match="draft_layers"):
        make_speculative_decode_window(cfg, window=8, draft_len=2,
                                       draft_layers=cfg.num_layers)
    with pytest.raises(ValueError, match="draft_len"):
        make_speculative_decode_window(cfg, window=8, draft_len=0,
                                       draft_layers=1)
    rec = smoke_config("recurrentgemma-2b")
    with pytest.raises(ValueError, match="full-attention"):
        Replica(rec, config=EngineConfig(window=8, speculate=True))


def test_perf_options_spec_knobs():
    perf = PerfOptions.parse("window=8,spec=1,dlen=4,dlayers=2")
    assert perf.speculate is True
    assert perf.draft_len == 4 and perf.draft_layers == 2
    assert PerfOptions().speculate is False


def test_spec_serve_group(env):
    """ServeGroup threads speculation through shared jitted programs: the
    fleet serves to completion with every response OK and acceptance > 0."""
    cfg, _ = env
    group = ServeGroup(cfg, nranks=2,
                       config=EngineConfig(num_slots=2, max_len=MAX_LEN,
                                           window=K, speculate=True,
                                           draft_len=D, draft_layers=1))
    reqs = _requests(6, max_new=10)
    result = group.serve(reqs)
    assert sorted(result.responses) == [r.id for r in reqs]
    assert all(r.ok for r in result.responses.values())
    accepted = sum(r.metrics.accepted_draft_tokens
                   for r in (result.report(i) for i in range(2)) if r)
    assert accepted > 0
