"""Device-channel tests: error-word lattice, enumeration (ref + shard_map port),
DeviceFuture semantics, probes, in-step fault injection."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommCorruptedError,
    DeviceFuture,
    ErrorCode,
    PropagatedError,
    combine_words,
    decode_table,
    enumerate_errors_ref,
)
from repro.core.detect import ProbeConfig, grad_probe, loss_probe, data_probe, step_probe
from repro.core.faults import (
    INJ_NAN_GRAD,
    INJ_NAN_LOSS,
    inject_grads,
    inject_loss,
)


def test_enumerate_ref_basic():
    words = jnp.array([0, 5, 0, 9, 0, 0, 3, 0], dtype=jnp.uint32)
    count, table = enumerate_errors_ref(words)
    errs = decode_table(int(count), np.asarray(table))
    assert [(e.rank, e.code) for e in errs] == [(1, 5), (3, 9), (6, 3)]


def test_enumerate_ref_empty_and_full():
    words = jnp.zeros(16, jnp.uint32)
    count, table = enumerate_errors_ref(words)
    assert int(count) == 0
    assert np.all(np.asarray(table) == 0)

    words = jnp.full(4, 7, jnp.uint32)
    count, table = enumerate_errors_ref(words)
    errs = decode_table(int(count), np.asarray(table))
    assert [(e.rank, e.code) for e in errs] == [(0, 7), (1, 7), (2, 7), (3, 7)]


def test_device_future_raises_propagated():
    word = jnp.uint32(int(ErrorCode.NONFINITE_LOSS))
    fut = DeviceFuture(outputs="state", word=word)
    with pytest.raises(PropagatedError) as ei:
        fut.wait()
    assert ei.value.combined_code & ErrorCode.NONFINITE_LOSS


def test_device_future_ok_passthrough():
    fut = DeviceFuture(outputs={"x": 1}, word=jnp.uint32(0))
    assert fut.wait() == {"x": 1}
    assert fut.result() == {"x": 1}  # idempotent


def test_device_future_window_fault_steps():
    """Window semantics: the (K, slots) history attributes a fault to its
    exact (step, slot); clean slots report -1."""
    hist = jnp.array([[0, 0, 0],
                      [0, 9, 0],
                      [3, 9, 0]], dtype=jnp.uint32)     # (K=3, slots=3)
    word = jnp.uint32(3 | 9)
    fut = DeviceFuture(outputs=None, word=word, history=hist)
    np.testing.assert_array_equal(fut.fault_steps(), [2, 1, -1])
    with pytest.raises(PropagatedError):
        fut.wait()
    # no history → no step attribution (per-step futures)
    assert DeviceFuture(outputs=None, word=word).fault_steps() is None


def test_device_future_corrupted():
    word = jnp.uint32(int(ErrorCode.COMM_CORRUPTED))
    fut = DeviceFuture(outputs=None, word=word)
    with pytest.raises(CommCorruptedError):
        fut.wait()


def test_loss_probe():
    cfg = ProbeConfig(loss_divergence_threshold=100.0)
    assert int(loss_probe(jnp.float32(1.0), cfg)) == 0
    assert int(loss_probe(jnp.float32(jnp.nan), cfg)) & int(ErrorCode.NONFINITE_LOSS)
    assert int(loss_probe(jnp.float32(jnp.inf), cfg)) & int(ErrorCode.NONFINITE_LOSS)
    assert int(loss_probe(jnp.float32(1e4), cfg)) & int(ErrorCode.DIVERGENCE)


def test_grad_probe_kernel_vs_ref():
    cfg = ProbeConfig(overflow_threshold=10.0)
    clean = {"a": jnp.ones((64, 130)), "b": jnp.zeros((7,))}
    assert int(grad_probe(clean, cfg)) == 0
    dirty = {"a": jnp.ones((64, 130)).at[3, 5].set(jnp.nan), "b": jnp.zeros((7,))}
    assert int(grad_probe(dirty, cfg)) & int(ErrorCode.NONFINITE_GRAD)
    hot = {"a": jnp.ones((64, 130)).at[0, 0].set(100.0), "b": jnp.zeros((7,))}
    assert int(grad_probe(hot, cfg)) & int(ErrorCode.OVERFLOW)


def test_data_probe():
    ok = jnp.array([[1, 2], [3, 4]], dtype=jnp.int32)
    assert int(data_probe(ok, vocab_size=10)) == 0
    bad = jnp.array([[1, -2], [3, 4]], dtype=jnp.int32)
    assert int(data_probe(bad, vocab_size=10)) & int(ErrorCode.DATA_FAULT)
    big = jnp.array([[1, 2], [3, 40]], dtype=jnp.int32)
    assert int(data_probe(big, vocab_size=10)) & int(ErrorCode.DATA_FAULT)


def test_injection_inside_jit():
    @jax.jit
    def step(x, inject):
        loss = jnp.mean(x)
        loss = inject_loss(loss, inject)
        grads = {"w": x}
        grads = inject_grads(grads, inject)
        word = step_probe(loss, grads, cfg=ProbeConfig())
        return loss, word

    x = jnp.ones((8, 8))
    _, w0 = step(x, jnp.uint32(0))
    assert int(w0) == 0
    _, w1 = step(x, jnp.uint32(INJ_NAN_LOSS))
    assert int(w1) & int(ErrorCode.NONFINITE_LOSS)
    _, w2 = step(x, jnp.uint32(INJ_NAN_GRAD))
    assert int(w2) & int(ErrorCode.NONFINITE_GRAD)


def test_combine_words():
    a = jnp.uint32(int(ErrorCode.NONFINITE_LOSS))
    b = jnp.uint32(int(ErrorCode.OVERFLOW))
    c = combine_words(a, b)
    assert ErrorCode(int(c)) == ErrorCode.NONFINITE_LOSS | ErrorCode.OVERFLOW


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import enumerate_errors_ref, make_enumerate_fn
kw = ({"axis_types": (jax.sharding.AxisType.Auto,)}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((8,), ("ranks",), **kw)
run = make_enumerate_fn(mesh, "ranks")
rng = np.random.default_rng(0)
for trial in range(20):
    words = rng.choice([0, 0, 0, 3, 5, 9], size=8).astype(np.uint32)
    words_j = jnp.asarray(words)
    c1, t1 = run(jax.device_put(
        words_j, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("ranks"))))
    c2, t2 = enumerate_errors_ref(words_j)
    assert int(c1) == int(c2), (trial, int(c1), int(c2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
print("MULTIDEV_OK")
"""


def test_enumeration_shardmap_matches_ref_multidevice():
    """The paper's scan/bcast/allreduce enumeration as a shard_map program over 8
    simulated devices must match the pure-jnp oracle (run in a subprocess so the
    main test process keeps a single CPU device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], cwd="/root/repo",
                         env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_OK" in out.stdout
