"""Focused unit tests: MoE dispatch correctness, chunked CE equivalence,
communicator dup/split semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import initialize, run_ranks
from repro.models.layers import (
    chunked_cross_entropy,
    softmax_cross_entropy,
)
from repro.models.moe import apply_moe, init_moe


# ------------------------------------------------------------------------- MoE
def _moe_dense_ref(p, x, cfg):
    """Oracle: route every token through its top-k experts with no capacity."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x)
    for e in range(E):
        h = x @ p["wi"][e]
        if cfg.mlp_kind == "swiglu":
            h = jax.nn.silu(x @ p["wg"][e]) * h
        y = h @ p["wo"][e]
        w = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
        out = out + y * w[..., None].astype(x.dtype)
    return out


def test_moe_matches_dense_reference_when_capacity_ample():
    cfg = smoke_config("qwen3-moe-30b-a3b").replace(
        expert_capacity_factor=8.0)      # ample capacity ⇒ nothing dropped
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    got, aux = apply_moe(p, x, cfg)
    want = _moe_dense_ref(p, x, cfg)
    assert float(aux["dropped_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_drops_under_tight_capacity():
    cfg = smoke_config("qwen3-moe-30b-a3b").replace(
        expert_capacity_factor=0.05)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    got, aux = apply_moe(p, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.0   # feeds ROUTER_OVERFLOW probe
    assert bool(jnp.all(jnp.isfinite(got)))


def test_moe_grads_flow():
    cfg = smoke_config("phi3.5-moe-42b-a6.6b")
    key = jax.random.PRNGKey(2)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))

    def loss(p):
        y, _ = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


# -------------------------------------------------------------------- chunked CE
@pytest.mark.parametrize("S,chunk", [(16, 8), (16, 16), (20, 8), (7, 8)])
def test_chunked_ce_matches_full(S, chunk):
    key = jax.random.PRNGKey(3)
    B, d, V = 3, 16, 37
    x = jax.random.normal(key, (B, S, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)

    full = softmax_cross_entropy((x @ w)[..., :V].astype(jnp.float32), labels)
    chunked = chunked_cross_entropy(x, labels, lambda xc: xc @ w, chunk)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)

    # gradients too (the backward recomputes logits per chunk)
    gf = jax.grad(lambda x_: softmax_cross_entropy(x_ @ w, labels))(x)
    gc = jax.grad(lambda x_: chunked_cross_entropy(
        x_, labels, lambda xc: xc @ w, chunk))(x)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gf),
                               rtol=1e-4, atol=1e-6)


# --------------------------------------------------------------- comm dup/split
def test_dup_isolates_tag_space():
    """Messages on a dup'ed communicator never match the parent's receives."""
    def fn(ctx):
        inst = initialize(ctx, default_timeout=10.0)
        comm = inst.comm_world()
        dup = comm.duplicate()
        if ctx.rank == 0:
            dup.send("on-dup", dst=1, tag=7).wait()
            comm.send("on-parent", dst=1, tag=7).wait()
            return "sent"
        a = comm.recv(src=0, tag=7).wait()   # must get the parent's message
        b = dup.recv(src=0, tag=7).wait()
        return (a, b)

    res = run_ranks(2, fn)
    assert res[1].exception is None, res[1].exception
    assert res[1].value == ("on-parent", "on-dup")


def test_split_subcommunicator():
    def fn(ctx):
        inst = initialize(ctx, default_timeout=10.0)
        comm = inst.comm_world()
        sub = comm.split([0, 2])             # ranks 0 and 2 only
        if ctx.rank in (0, 2):
            assert sub is not None and sub.size == 2
            local = sub.rank
            other = 1 - local
            f = sub.send(ctx.rank, dst=other)
            got = sub.recv(src=other).wait()
            f.wait()
            return got
        assert sub is None
        return "excluded"

    res = run_ranks(3, fn)
    for r in res:
        assert r.exception is None, r.exception
    assert res[0].value == 2 and res[2].value == 0
    assert res[1].value == "excluded"
