"""Elastic serve group: durable ledger, crash-restart replay, regrow.

Covers the PR-8 robustness layer from the bottom up:

* the write-ahead log's torn-write contract (a truncated *final* record is a
  legal crash artefact and is discarded; the same damage mid-log is fatal),
* compaction bounding the log while preserving replay,
* the queue's ahead-of-class requeue ordering across repeated
  requeue/re-route cycles and across a ledger-replay re-admission,
* the autoscaler's hysteresis (grow on sustained backlog, shrink on idle,
  cooldown between decisions, floor on the member count), and
* the end-to-end acceptance story: kill a rank mid-flight, stop the whole
  fleet, restart from the ledger alone, regrow to full size via the
  non-blocking join — zero drops, every stream bit-exact against a clean
  run, and the merged two-incarnation trace passes the post-mortem check.
"""
import json
import os
from types import SimpleNamespace

import pytest

from repro.configs import smoke_config
from repro.core.faults import FaultSchedule, FaultSpec
from repro.obs import postmortem
from repro.obs.trace import NULL_TRACER, merge_trace_dicts
from repro.serve.config import EngineConfig
from repro.serve.group import AutoscalePolicy, ServeGroup
from repro.serve.ledger import (
    GroupLedger,
    LedgerCorrupt,
    WriteAheadLog,
    replay,
    request_record,
    response_record,
)
from repro.serve.queue import OK, Request, RequestQueue, Response


def _req(i, max_new=8):
    return Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=max_new)


# ------------------------------------------------------------------- the WAL
class TestWriteAheadLog:
    def test_torn_final_record_discarded_not_fatal(self, tmp_path):
        path = str(tmp_path / "ledger.wal")
        wal = WriteAheadLog(path)
        for i in range(3):
            wal.append(request_record(_req(i)))
        wal.close()
        # crash mid-write: chop the final record in half
        size = os.path.getsize(path)
        with open(path, "r+") as f:
            f.truncate(size - 20)
        rep = replay(path)
        assert rep.torn == 1
        assert sorted(rep.requests) == [0, 1]
        assert [r.id for r in rep.outstanding()] == [0, 1]

    def test_reopen_truncates_torn_tail_and_continues(self, tmp_path):
        path = str(tmp_path / "ledger.wal")
        wal = WriteAheadLog(path)
        for i in range(3):
            wal.append(request_record(_req(i)))
        wal.close()
        with open(path, "r+") as f:
            f.truncate(os.path.getsize(path) - 20)
        # the restart reopens the log: the garbage tail must be gone so the
        # continued log replays with zero torn records
        wal2 = WriteAheadLog(path)
        wal2.append(request_record(_req(7)))
        wal2.close()
        rep = replay(path)
        assert rep.torn == 0
        assert sorted(rep.requests) == [0, 1, 7]

    def test_midfile_corruption_is_fatal(self, tmp_path):
        path = str(tmp_path / "ledger.wal")
        wal = WriteAheadLog(path)
        for i in range(3):
            wal.append(request_record(_req(i)))
        wal.close()
        lines = open(path).read().splitlines()
        # valid JSON, wrong checksum: an fsync-acknowledged record that no
        # longer matches its CRC is damage, not a crash artefact
        assert '"kind":"submit"' in lines[1]
        lines[1] = lines[1].replace('"kind":"submit"', '"kind":"sabmit"')
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        with pytest.raises(LedgerCorrupt):
            replay(path)

    def test_compaction_bounds_log_and_preserves_replay(self, tmp_path):
        path = str(tmp_path / "ledger.wal")
        reqs = [_req(i) for i in range(20)]
        led = GroupLedger(reqs, ranks=(0, 1),
                          wal=WriteAheadLog(path, compact_every=8))
        for rank in (0, 1):
            led.take(rank)
        for i in range(16):
            led.complete(Response(id=i, status=OK, tokens=(1, 2), replica=0))
        led.wal.close()
        # 20 submits + epoch + routes + 16 retires would be 50+ records; the
        # compactor collapsed the history into a bounded snapshot tail
        n_lines = sum(1 for _ in open(path))
        assert n_lines <= 16
        rep = replay(path)
        assert sorted(rep.responses) == list(range(16))
        assert [r.id for r in rep.outstanding()] == [16, 17, 18, 19]
        assert rep.members == (0, 1)


# --------------------------------------------- torn epoch transitions
class TestTornEpochTransition:
    """A supervisor/fleet crash *during* an epoch transition (the multihost
    supervisor's eviction path and the thread-rank shrink both drive it):
    ``on_death`` appends the shrink's epoch record first, then one route
    record per re-routed request — so a crash can tear the log mid-epoch
    (the transition never happened) or mid-route (the transition happened,
    a re-route didn't). Replay must come back to a consistent membership +
    outstanding set at BOTH tear points, and a restarted group must serve
    the replayed backlog to completion, bit-exact."""

    N = 9

    def _mid_transition_wal(self, tmp_path, retire=()):
        path = str(tmp_path / "ledger.wal")
        led = GroupLedger([_req(i) for i in range(self.N)], ranks=(0, 1, 2),
                          wal=WriteAheadLog(path))
        for rank in (0, 1, 2):
            led.take(rank)
        for rid in retire:
            led.complete(Response(id=rid, status=OK, tokens=(1, 2),
                                  replica=rid % 3))
        moved = led.on_death([2])
        assert moved, "the dead rank had nothing outstanding"
        led.wal.close()
        return path, moved

    @staticmethod
    def _lines(path):
        with open(path, "rb") as f:
            return f.read().splitlines(keepends=True)

    @staticmethod
    def _tear_into(path, lines, idx):
        """Crash artefact: everything before line ``idx`` is intact, line
        ``idx`` was mid-write (half its bytes), everything after is gone."""
        with open(path, "wb") as f:
            f.writelines(lines[:idx])
            f.write(lines[idx][:max(len(lines[idx]) // 2, 1)])

    def _last_epoch_idx(self, lines):
        return max(i for i, ln in enumerate(lines)
                   if b'"kind":"epoch"' in ln)

    def test_torn_epoch_record_replays_pre_transition_membership(
            self, tmp_path):
        path, _ = self._mid_transition_wal(tmp_path, retire=(0, 1))
        lines = self._lines(path)
        self._tear_into(path, lines, self._last_epoch_idx(lines))
        rep = replay(path)
        assert rep.torn == 1
        # the transition never happened: epoch 0, full membership, and the
        # dead rank still owns its share on the record — the restart will
        # re-run the shrink, not trust a half-written one
        assert rep.epoch == 0
        assert rep.members == (0, 1, 2)
        assert sorted(rep.responses) == [0, 1]
        assert [r.id for r in rep.outstanding()] == [
            i for i in range(self.N) if i not in (0, 1)]
        assert any(rank == 2 for rank in rep.routes.values())

    def test_torn_route_record_keeps_membership_and_outstanding_set(
            self, tmp_path):
        path, moved = self._mid_transition_wal(tmp_path, retire=(0, 1))
        lines = self._lines(path)
        epoch_idx = self._last_epoch_idx(lines)
        route_idx = next(i for i in range(epoch_idx + 1, len(lines))
                         if b'"kind":"route"' in lines[i])
        self._tear_into(path, lines, route_idx)
        rep = replay(path)
        assert rep.torn == 1
        # the transition DID happen (its record was fsync'd before any
        # route): shrunk membership replays...
        assert rep.epoch == 1
        assert rep.members == (0, 1)
        # ...and the torn re-route is discarded, never half-applied: the
        # moved requests' last recorded owner is still the dead rank, but
        # every one of them is in the outstanding set — membership and the
        # re-submission set stay consistent, nothing is dropped
        moved_ids = sorted(rid for rid, _, _ in moved)
        assert all(rep.routes[rid] == 2 for rid in moved_ids)
        outstanding = {r.id for r in rep.outstanding()}
        assert set(moved_ids) <= outstanding
        assert outstanding == {i for i in range(self.N) if i not in (0, 1)}

    def test_restart_from_torn_transition_serves_to_completion(
            self, group, tmp_path):
        clean = group.serve([_req(i) for i in range(self.N)])
        assert all(r.ok for r in clean.responses.values())
        path, _ = self._mid_transition_wal(tmp_path)     # nothing retired
        lines = self._lines(path)
        self._tear_into(path, lines, self._last_epoch_idx(lines))
        r2 = group.serve_from_ledger(path)
        assert sorted(r2.responses) == list(range(self.N)), (
            "requests dropped across the torn epoch transition")
        assert all(r.ok for r in r2.responses.values())
        for rid, resp in r2.responses.items():
            assert tuple(resp.tokens) == tuple(clean.responses[rid].tokens), (
                f"request {rid} diverged after the torn-transition replay")


# ------------------------------------------------------- requeue ordering
class TestRequeueOrdering:
    def test_ahead_of_class_across_repeated_cycles(self):
        q = RequestQueue()
        for i in range(8):
            assert q.submit(_req(i)) is None
        assigned: list[int] = []     # every ahead-of-class key ever handed out
        for _ in range(5):           # repeated requeue/re-route cycles
            a, b = q.pop(), q.pop()
            q.requeue(b)
            q.requeue(a)
            # negative-sequence keys: unique within the heap and never reused
            seqs = [entry[1] for entry in q._heap]
            assert len(seqs) == len(set(seqs))
            for s in (s for s in seqs if s < 0):
                if s not in assigned:
                    assigned.append(s)
            assert len(assigned) == len(set(assigned))
            # newest requeue pops first, ahead of every plain submit
            got = q.pop()
            assert got.id == a.id
            q.requeue(got)
        # after all the churn, every request is still exactly once in line
        drained = []
        while len(q):
            drained.append(q.pop().id)
        assert sorted(drained) == list(range(8))

    def test_replay_readmission_keeps_requeued_ahead(self):
        q1 = RequestQueue()
        reqs = [_req(i) for i in range(4)]
        for r in reqs:
            q1.submit(r)
        # crash: a fresh incarnation re-admits the replayed (already
        # arrival-stamped) requests via requeue — the Replica.readmit path —
        # then takes brand-new submissions on top
        q2 = RequestQueue()
        for r in reqs:
            assert r.arrival_t is not None
            q2.requeue(r)
        fresh = _req(99)
        q2.submit(fresh)
        order = [q2.pop().id for _ in range(5)]
        assert order[-1] == 99               # new work waits its turn
        assert sorted(order[:4]) == [0, 1, 2, 3]


# ------------------------------------------------------------- group fixture
@pytest.fixture(scope="module")
def group():
    return ServeGroup(smoke_config("recurrentgemma-2b"), 3, max_ranks=4,
                      config=EngineConfig(num_slots=2, max_len=48, window=4,
                                          overlap=True, trace=True))


# --------------------------------------------------------------- autoscaler
class TestAutoscaler:
    def _tick(self, group, led, pol, round_i, report):
        group._autoscale_tick(led, pol, None, round_i, NULL_TRACER, report)

    def test_grows_only_on_sustained_backlog(self, group):
        led = GroupLedger([_req(i) for i in range(8)], ranks=(0, 1),
                          spares=(2,))
        pol = AutoscalePolicy(queue_high=2, grow_sustain=3, cooldown=0)
        report = SimpleNamespace(events=[])
        for r in range(2):           # pressure, but not sustained yet
            self._tick(group, led, pol, r, report)
            assert led.autoscale_events == []
        self._tick(group, led, pol, 2, report)
        assert led.autoscale_events == [
            {"round": 2, "action": "grow", "rank": 2}]
        assert led.summoned(2) == "autoscale"
        # spares exhausted: continued pressure cannot over-grow
        for r in range(3, 8):
            self._tick(group, led, pol, r, report)
        assert len(led.autoscale_events) == 1

    def test_cooldown_separates_grow_decisions(self, group):
        led = GroupLedger([_req(i) for i in range(8)], ranks=(0, 1),
                          spares=(2, 3))
        pol = AutoscalePolicy(queue_high=2, grow_sustain=1, cooldown=10)
        report = SimpleNamespace(events=[])
        for r in range(10):
            self._tick(group, led, pol, r, report)
        assert [e["rank"] for e in led.autoscale_events] == [2]
        self._tick(group, led, pol, 10, report)     # cooldown elapsed
        assert [e["rank"] for e in led.autoscale_events] == [2, 3]

    def test_shrinks_on_idle_down_to_the_floor(self, group):
        led = GroupLedger([_req(i) for i in range(6)], ranks=(0, 1, 2))
        for rank in (0, 1, 2):
            led.take(rank)           # backlog drained, work still in flight
        pol = AutoscalePolicy(queue_high=2, shrink_idle=3, cooldown=0,
                              min_ranks=2)
        report = SimpleNamespace(events=[])
        for r in range(2):
            self._tick(group, led, pol, r, report)
            assert led.leaving is None
        self._tick(group, led, pol, 2, report)
        assert led.leaving == 2      # highest non-leader rank drains out
        assert led.autoscale_events == [
            {"round": 2, "action": "shrink", "rank": 2}]
        # one graceful leave at a time, and never below the floor
        for r in range(3, 8):
            self._tick(group, led, pol, r, report)
        assert len(led.autoscale_events) == 1
        led2 = GroupLedger([_req(0)], ranks=(0, 1))
        led2.take(0), led2.take(1)
        report2 = SimpleNamespace(events=[])
        for r in range(8):
            self._tick(group, led2, pol, r, report2)
        assert led2.leaving is None and led2.autoscale_events == []


# ---------------------------------------------------------- join/drain race
class TestJoinDrainRace:
    def test_scheduled_join_survives_full_drain(self, group):
        # a tiny workload drains long before the summoned spare finishes its
        # (stretched) state transfer; the survivors must hold the final close
        # at the pending-join / stale-epoch gate until the join lands.
        # Regression: the join's epoch proposal used to race the close — a
        # survivor whose exchange pre-dated the proposal saw no pending join
        # and a stale agreed epoch, closed, and stranded the joiner.
        old = group.transfer_chunks
        group.transfer_chunks = 60          # ~120 ms, many idle gate rounds
        try:
            res = group.serve([_req(i, max_new=4) for i in range(4)],
                              joins=[1])
        finally:
            group.transfer_chunks = old
        assert sorted(res.responses) == list(range(4))
        assert all(r.ok for r in res.responses.values())
        assert 3 in res.joined
        names = [e["name"] for e in res.trace()["traceEvents"]]
        assert "replica_join" in names      # the join truly completed
        assert "state_transfer" in names


# ------------------------------------------------------------ the whole story
class TestCrashReplayRegrow:
    def test_kill_crash_replay_regrow_end_to_end(self, group, tmp_path):
        path = str(tmp_path / "ledger.wal")
        mk = lambda: [_req(i, max_new=10) for i in range(30)]
        clean = group.serve(mk())
        assert all(r.ok for r in clean.responses.values())

        # act 1: rank 2 dies at round 2, then the WHOLE fleet stops at
        # round 5 — only the fsync'd ledger survives
        r1 = group.serve(
            mk(), faults=FaultSchedule(
                [FaultSpec(step=2, kind="kill", rank=2)]),
            ledger_path=path, crash_at=5)
        assert r1.crashed
        assert len(r1.responses) < 30

        # act 2: a new incarnation restarts from the ledger alone, replays
        # the outstanding set onto the survivors, and regrows to 3 ranks by
        # re-admitting the killed rank through the non-blocking join
        r2 = group.serve_from_ledger(path, joins=[1])
        merged_responses = {**r1.responses, **r2.responses}
        assert sorted(merged_responses) == list(range(30))       # zero drops
        assert all(r.ok for r in merged_responses.values())
        assert 2 in r2.joined
        assert r2.epoch >= 2         # kill-shrink epoch + join epoch
        assert r2.replayed           # requests re-admitted from the ledger

        # bit-exactness: the crash, the replay and the regrow are invisible
        # in the token streams
        for rid, resp in merged_responses.items():
            assert tuple(resp.tokens) == tuple(clean.responses[rid].tokens), (
                f"request {rid} diverged from the clean run")

        # one causal story across both incarnations: the merged trace passes
        # the same check `trace_tool.py --check` runs, and the kill chains
        # through the shrink to the rejoin
        merged = merge_trace_dicts(r1.trace(), r2.trace())
        assert postmortem.validate(merged) == []
        chains = postmortem.group_chains(merged)
        assert any(c["dead_rank"] == 2 and c["shrinks"] and c["rejoins"]
                   for c in chains)
        names = {e["name"] for e in merged["traceEvents"]
                 if e.get("cat") == "group"}
        assert {"replica_kill", "ulfm_shrink", "fleet_stop", "ledger_replay",
                "state_transfer", "replica_join"} <= names
