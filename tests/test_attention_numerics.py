"""Chunked (custom-VJP flash) attention vs naive oracle: values AND gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import sdpa_chunked, sdpa_ref

CASES = [
    # (B, S, T, Hq, Hkv, D, causal, window, q_chunk, kv_chunk)
    (2, 16, 16, 4, 2, 8, True, 0, 8, 8),
    (2, 16, 16, 4, 4, 8, False, 0, 8, 4),     # encoder (bidirectional, MHA)
    (1, 32, 32, 4, 1, 16, True, 8, 8, 8),     # sliding window, MQA
    (2, 24, 24, 6, 2, 8, True, 0, 8, 16),     # uneven chunk split
    (1, 17, 17, 2, 1, 8, True, 0, 8, 8),      # padding (S not chunk multiple)
    (1, 16, 16, 8, 2, 4, True, 5, 4, 4),      # window not chunk-aligned
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_ref(case):
    B, S, T, Hq, Hkv, D, causal, window, qc, kc = case
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)
    got = sdpa_chunked(q, k, v, causal=causal, window=window,
                       q_chunk=qc, kv_chunk=kc)
    want = sdpa_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("case", CASES)
def test_gradients_match_ref(case):
    B, S, T, Hq, Hkv, D, causal, window, qc, kc = case
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)

    def loss_chunked(q, k, v):
        o = sdpa_chunked(q, k, v, causal=causal, window=window,
                         q_chunk=qc, kv_chunk=kc)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        o = sdpa_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"grad d{name}")


def test_bf16_dtypes():
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 16, 4, 8), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 16, 2, 8), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 16, 2, 8), jnp.bfloat16)
    got = sdpa_chunked(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    want = sdpa_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
