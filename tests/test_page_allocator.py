"""PageAllocator unit tests: pure host-side ledger arithmetic.

The allocator is deliberately JAX-free, so its invariants — exact free-count
accounting under interleaved alloc/free (fragmentation), exhaustion without
partial effect, double-free rejection, watermark admission — are checked
here without a device in sight. Replica-level behaviour (eviction, LFLR
reclaim, bit-exactness) lives in ``test_serve_paged.py``.
"""
import pytest

from repro.serve import PageAllocator, PagePoolExhausted


def test_pages_for_rounds_up():
    a = PageAllocator(8, 4)
    assert a.pages_for(0) == 0
    assert a.pages_for(1) == 1
    assert a.pages_for(4) == 1
    assert a.pages_for(5) == 2
    assert a.pages_for(17) == 5


def test_interleaved_alloc_free_fragmentation():
    """Interleaved alloc/free shreds the physical id space; the ledger must
    keep exact counts, never hand out an owned page, and still reach full
    utilisation — fragmentation cannot degrade a table-indirected pool."""
    a = PageAllocator(16, 4)
    a.alloc(0, 4)
    a.alloc(1, 3)
    a.alloc(2, 5)
    assert a.free_pages == 4
    a.free_slot(1)                       # hole in the middle of the id space
    assert a.free_pages == 7
    a.alloc(3, 2)
    a.free_slot(0)                       # second hole
    a.alloc(4, 6)                        # spans both holes
    assert a.free_pages == 16 - 5 - 2 - 6
    a.check()
    # full utilisation despite the churn
    a.alloc(5, a.free_pages)
    assert a.free_pages == 0
    a.check()
    # every page owned exactly once
    owned = [p for s in (2, 3, 4, 5) for p in a.owned(s)]
    assert len(owned) == len(set(owned)) == 16


def test_exhaustion_raises_without_partial_effect():
    a = PageAllocator(4, 2)
    a.alloc(0, 3)
    with pytest.raises(PagePoolExhausted):
        a.alloc(1, 2)
    assert a.free_pages == 1             # nothing was consumed by the failure
    assert not a.owns(1)
    a.alloc(1, 1)                        # the remaining page still allocs
    assert a.free_pages == 0
    a.check()


def test_double_free_rejected():
    a = PageAllocator(8, 4)
    a.alloc(0, 2)
    freed = a.free_slot(0)
    assert len(freed) == 2 and a.free_pages == 8
    with pytest.raises(ValueError, match="double free"):
        a.free_slot(0)
    with pytest.raises(ValueError, match="double free"):
        a.free_slot(3)                   # never owned anything
    a.check()


def test_owned_preserves_logical_page_order():
    """owned() must keep allocation (= logical page) order: index i of the
    table row holds positions [i*page_size, (i+1)*page_size)."""
    a = PageAllocator(8, 4)
    first = a.alloc(0, 2)
    second = a.alloc(0, 3)
    assert list(a.owned(0)) == first + second


def test_watermark_admission():
    a = PageAllocator(8, 4, watermark=2)
    assert a.can_admit(16)               # 4 pages <= 8 free - 2 watermark
    # 7 pages + 2 watermark > 8 total: the gated check could NEVER pass, so
    # the headroom is waived — an accepted request must not defer forever
    assert a.can_admit(28)
    a.alloc(0, 4)
    assert a.can_admit(8)                # 2 <= 4 - 2
    assert not a.can_admit(12)           # 3 > 2 (headroom applies: 3+2 <= 8)
    assert not a.can_admit(28)           # waived headroom, but 7 > 4 free
    a.free_slot(0)
    assert a.can_admit(24)               # 6+2 <= 8: gated, 6 <= 8-2


def test_constructor_validation():
    with pytest.raises(ValueError):
        PageAllocator(0, 4)
    with pytest.raises(ValueError):
        PageAllocator(4, 0)
    with pytest.raises(ValueError):
        PageAllocator(4, 4, watermark=-1)
    with pytest.raises(ValueError):
        PageAllocator(4, 4).alloc(0, -1)


def test_multi_page_growth_spans_watermark_boundary():
    """One alloc call growing a lane by several pages — the `[pos, pos+K+D)`
    growth path the speculative window exercises — may dip INTO the watermark
    headroom: the watermark gates *admission* of new sequences only, never the
    growth of lanes already serving (a grown lane must not deadlock against
    its own headroom). Ledger arithmetic must stay exact across the boundary
    and can_admit must flip to refusing exactly when the headroom is gone."""
    a = PageAllocator(8, 4, watermark=2)
    a.alloc(0, 3)                        # free = 5, admission headroom left
    assert a.can_admit(8)                # 2 <= 5 - 2
    # single-call growth of 4 pages: crosses free=watermark (5 -> 1 < 2)
    got = a.alloc(0, 4)
    assert len(got) == 4 and a.free_pages == 1
    assert a.owned(0)[-4:] == tuple(got)  # logical page order kept
    a.check()
    # admission now refused (1 free - 2 watermark < anything)...
    assert not a.can_admit(4)
    # ...but in-flight growth still succeeds down to the last page
    a.alloc(1, 1)
    assert a.free_pages == 0
    a.check()
    # and exhaustion past that still raises without partial effect
    with pytest.raises(PagePoolExhausted):
        a.alloc(0, 1)
    assert a.free_pages == 0
    a.check()
