"""Unit tests for the seedable, validating fault-injection schedule.

S1/S2 of the fuzzer PR: every random choice a schedule feeds its consumers
replays bit-for-bit from the seed alone, and the injection surfaces reject
unknown kinds and non-injectable ErrorCode words loudly instead of dropping
them on the floor.
"""
import numpy as np
import pytest

from repro.core.errors import ATTRIBUTION_ONLY, ErrorCode
from repro.core.faults import (
    INJ_NAN_LOSS,
    INJECTABLE_CODE_MASK,
    KNOWN_KINDS,
    FaultSchedule,
    FaultSpec,
    apply_host_fault,
    validate_injectable_code,
)

SOFT = ErrorCode.NONFINITE_LOSS
STRUCT = ErrorCode.PAGE_FAULT


# ------------------------------------------------------- injectable-code mask
class TestValidateInjectableCode:
    def test_every_single_bit_injectable_class_passes(self):
        for cls in ErrorCode(INJECTABLE_CODE_MASK).classes():
            assert validate_injectable_code(cls) == int(cls)

    def test_combined_soft_word_passes(self):
        word = int(SOFT | ErrorCode.OVERFLOW | STRUCT)
        assert validate_injectable_code(word) == word

    def test_zero_word_rejected(self):
        with pytest.raises(ValueError, match="OK"):
            validate_injectable_code(0)

    def test_attribution_only_rejected(self):
        with pytest.raises(ValueError, match="DRAFT_REJECT"):
            validate_injectable_code(ATTRIBUTION_ONLY)

    def test_hard_fault_bits_rejected(self):
        for hard in (ErrorCode.RANK_FAILED, ErrorCode.COMM_CORRUPTED):
            with pytest.raises(ValueError, match=hard.name):
                validate_injectable_code(hard)

    def test_undefined_bit_rejected(self):
        with pytest.raises(ValueError, match="not injectable"):
            validate_injectable_code(1 << 30)

    def test_one_bad_bit_taints_a_valid_word(self):
        with pytest.raises(ValueError, match="DRAFT_REJECT"):
            validate_injectable_code(int(SOFT) | int(ATTRIBUTION_ONLY))

    def test_mask_excludes_exactly_the_forbidden_lanes(self):
        assert INJECTABLE_CODE_MASK & int(ATTRIBUTION_ONLY) == 0
        assert INJECTABLE_CODE_MASK & int(ErrorCode.RANK_FAILED) == 0
        assert INJECTABLE_CODE_MASK & int(ErrorCode.COMM_CORRUPTED) == 0
        assert INJECTABLE_CODE_MASK & int(SOFT)


# ------------------------------------------------------- schedule validation
class TestScheduleValidation:
    def test_unknown_kind_raises_at_read(self):
        sched = FaultSchedule([FaultSpec(step=1, kind="nan_los", rank=0)])
        with pytest.raises(ValueError, match="unknown fault kind"):
            sched.inject_word(1, 0)

    def test_known_kinds_cover_the_docstring(self):
        assert "code" in KNOWN_KINDS
        assert {"kill", "straggle", "user"} <= KNOWN_KINDS

    def test_code_spec_validated_even_via_inject_word(self):
        sched = FaultSchedule([FaultSpec(step=1, kind="code", rank=0,
                                         code=int(ATTRIBUTION_ONLY))])
        with pytest.raises(ValueError, match="DRAFT_REJECT"):
            sched.inject_word(1, 0)
        with pytest.raises(ValueError, match="DRAFT_REJECT"):
            sched.code_word(1, 0)

    def test_code_word_ors_scheduled_codes(self):
        sched = FaultSchedule([
            FaultSpec(step=2, kind="code", rank=0, code=int(SOFT)),
            FaultSpec(step=2, kind="code", rank=0, code=int(STRUCT)),
            FaultSpec(step=3, kind="code", rank=0, code=int(ErrorCode.USER)),
        ])
        assert sched.code_word(2, 0) == int(SOFT | STRUCT)
        assert sched.code_word(3, 0) == int(ErrorCode.USER)
        assert sched.code_word(4, 0) == 0
        # a "code" spec carries no INJ_* device bit of its own
        assert sched.inject_word(2, 0) == 0

    def test_device_and_host_fault_partition(self):
        specs = [FaultSpec(step=1, kind="nan_loss", rank=0),
                 FaultSpec(step=1, kind="code", rank=0, code=int(SOFT)),
                 FaultSpec(step=2, kind="kill", rank=1),
                 FaultSpec(step=3, kind="user", rank=0)]
        sched = FaultSchedule(specs)
        assert sched.device_faults() == specs[:2]
        assert sched.host_faults() == specs[2:]
        assert sched.inject_word(1, 0) == INJ_NAN_LOSS

    def test_apply_host_fault_rejects_device_kinds(self):
        with pytest.raises(ValueError, match="not a host fault kind"):
            apply_host_fault(FaultSpec(step=1, kind="nan_loss", rank=0))
        with pytest.raises(ValueError, match="not a host fault kind"):
            apply_host_fault(FaultSpec(step=1, kind="code", rank=0,
                                       code=int(SOFT)))

    def test_apply_host_fault_user_code(self):
        assert (apply_host_fault(FaultSpec(step=1, kind="user", rank=0))
                is ErrorCode.USER)


# ------------------------------------------------------------- seedability
class TestSeedability:
    def test_rng_for_replays_from_seed_alone(self):
        a = FaultSchedule(seed=7).rng_for(rank=1, step=3)
        b = FaultSchedule(seed=7).rng_for(rank=1, step=3)
        assert a.integers(1 << 30) == b.integers(1 << 30)

    def test_rng_for_differs_across_rank_and_step(self):
        base = FaultSchedule(seed=7)
        draws = {(r, s): int(base.rng_for(r, s).integers(1 << 30))
                 for r in range(3) for s in range(3)}
        assert len(set(draws.values())) == len(draws)

    def test_resolve_materialises_wildcards_deterministically(self):
        specs = [FaultSpec(step=2, kind="kill", rank=None),
                 FaultSpec(step=4, kind="kill", rank=None)]
        a = FaultSchedule(specs, seed=11).resolve(range(4))
        b = FaultSchedule(specs, seed=11).resolve(range(4))
        assert [s.rank for s in a.specs] == [s.rank for s in b.specs]
        assert all(s.rank in range(4) for s in a.specs)
        # a different seed may pick different victims; the draw is per-index,
        # so the two wildcard specs are resolved independently
        c = FaultSchedule(specs, seed=12).resolve(range(4))
        assert all(s.rank is not None for s in c.specs)

    def test_resolve_is_idempotent_and_preserves_concrete_ranks(self):
        specs = [FaultSpec(step=2, kind="kill", rank=3),
                 FaultSpec(step=4, kind="state_nan", rank=None)]
        once = FaultSchedule(specs, seed=5).resolve(range(6))
        twice = once.resolve(range(6))
        assert once.specs == twice.specs
        assert once.specs[0].rank == 3
        assert once.seed == 5

    def test_resolve_over_zero_ranks_raises(self):
        with pytest.raises(ValueError, match="zero ranks"):
            FaultSchedule([FaultSpec(step=1, kind="kill", rank=None)]
                          ).resolve([])
