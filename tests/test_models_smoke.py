"""Per-arch smoke tests: reduced same-family config, one forward + one train-grad
step + (where applicable) one decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_SHAPE, smoke_config
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS)


def _make_batch(cfg, key):
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    k1, k2 = jax.random.split(key)
    batch = {}
    if cfg.family == "audio":
        batch["inputs_embeds"] = jax.random.normal(k1, (B, S, cfg.d_model),
                                                   jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            jax.random.fold_in(k1, 7), (B, cfg.img_tokens, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_grad(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _make_batch(cfg, jax.random.fold_in(key, 1))

    logits, aux = jax.jit(model.forward)(
        batch.get("tokens"), **{}) if False else model.forward(
        params, batch.get("tokens"),
        inputs_embeds=batch.get("inputs_embeds"),
        img_embeds=batch.get("img_embeds"))
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"

    def loss_fn(p):
        loss, _ = model.loss(p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), f"loss={loss}"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), "non-finite grads"
    # a model with tied/untied embeddings must actually receive gradient signal
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if not ARCHS[a].is_encoder])
def test_decode_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, max_len = 2, 32
    cache = model.init_cache(B, max_len)
    token = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, token, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # a few more steps to exercise ring buffers / states
    for pos in range(1, 5):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = step(params, tok, cache, jnp.int32(pos))
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if not ARCHS[a].is_encoder
                                  and ARCHS[a].family != "vlm"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits must match the full-sequence forward (the
    decode path shares no code with the train path, so this is the strongest
    cheap consistency check we have)."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.fold_in(key, 3), (B, S), 0,
                                cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens, impl="ref")

    cache = model.init_cache(B, 32)
    step = jax.jit(model.decode_step)
    outs = []
    for pos in range(S):
        lg, cache = step(params, tokens[:, pos: pos + 1], cache, jnp.int32(pos))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)
