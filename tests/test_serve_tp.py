"""Tensor-parallel replicas: cross-shard error-word reconciliation (ISSUE 9).

A ``tp=2`` replica shards the decode/verify/prefill windows over a "model"
mesh axis (storage sharded, compute replicated inside the shard_mapped
window) and OR-folds the per-shard ``(K, slots)`` error words across the
axis, so a fault detected on any shard latches identically on all shards.
The contract under test:

* the TP engine's token streams are **bit-exact** vs the single-device
  window engine — steady state, faulted (LFLR re-prefill), paged
  (PAGE_FAULT reclaim) and speculative (DRAFT_REJECT attribution-only)
  alike;
* a shard-injected fault is indistinguishable at retirement from an
  all-shard one — same recovery, same per-``(step, slot)`` attribution,
  same streams;
* a TP shard loss inside a ServeGroup is a hard fault of the owning
  replica: RANK_FAILED → ULFM shrink → re-route, zero request drops;
* the fuzz corpus replays clean on the TP engine kit;
* :class:`~repro.serve.config.EngineConfig` is the one construction path —
  old kwargs still work for one release behind a ``DeprecationWarning``.

Runs on CPU with forced host devices (conftest sets
``XLA_FLAGS=--xla_force_host_platform_device_count=2``).
"""
import dataclasses
import pathlib
import warnings

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.errors import ErrorCode
from repro.core.errors import strip_codes
from repro.core.faults import FaultSchedule, FaultSpec
from repro.models import build_model
from repro.obs import postmortem
from repro.obs.trace import SHARD_TID, Tracer, merge_traces
from repro.serve import OK, EngineConfig, Replica, Request
from repro.serve.group import ServeGroup

MAX_LEN = 64
TP = 2

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < TP,
    reason=f"tp={TP} needs {TP} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _config(tp, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("window", 4)
    kw.setdefault("overlap", True)
    return EngineConfig(tp=tp, **kw)


def _replica(env, tp, *, config_kw=None, **kw):
    cfg, params = env
    return Replica(cfg, params=params, config=_config(tp, **(config_kw or {})),
                   **kw)


def _requests(n, max_new=8, prompt_len=5):
    return [Request(id=i, prompt=tuple(5 + i + j for j in range(prompt_len)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_all(rep, reqs):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps = {}, 0
    while not rep.idle():
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 500
    return out


def _streams(out):
    return {i: (r.status, tuple(r.tokens)) for i, r in out.items()}


# ---------------------------------------------------------------- bit-exactness
@pytest.mark.parametrize("variant", ["plain", "paged", "spec"])
def test_tp_token_bit_exact_vs_single_device(env, variant):
    """Every TP engine variant reproduces the single-device window engine's
    token streams exactly: sharded storage + replicated compute + the
    post-scan word fold must be invisible in the output."""
    kw = {}
    if variant == "paged":
        kw = dict(paged=True, page_size=8)
    elif variant == "spec":
        kw = dict(speculate=True, draft_len=2)
    ref = _streams(_serve_all(_replica(env, 1, config_kw=kw), _requests(4)))
    got = _streams(_serve_all(_replica(env, TP, config_kw=kw), _requests(4)))
    assert got == ref
    assert all(s == OK for s, _ in got.values())


# --------------------------------------------------------- shard reconciliation
def _shard_injector(shard, code, at=3):
    """Inject ``code`` at dispatch ``at``, window step 1, slot 0 — on one
    shard (``shard >= 0``) or on every shard (``shard = -1``)."""
    def inject(index, shape):
        if index != at or len(shape) != 3:
            return None
        w = np.zeros(shape, np.uint32)
        tgt = slice(None) if shard < 0 else shard
        w[tgt, 1, 0] = np.uint32(code)
        return w
    return inject


@pytest.mark.parametrize("shard", [0, 1, -1])
def test_shard_injected_fault_latches_on_all_shards(env, shard):
    """The OR-fold across the model axis makes a fault injected on one shard
    indistinguishable from one injected on all shards: same LFLR recovery,
    same per-(step, slot) attribution, same bit-exact final streams."""
    clean = _streams(_serve_all(_replica(env, TP), _requests(3)))
    tracer = Tracer(pid=0)
    rep = _replica(env, TP, tracer=tracer,
                   fault_injector=_shard_injector(
                       shard, int(ErrorCode.STATE_FAULT)))
    got = _serve_all(rep, _requests(3))
    assert _streams(got) == clean                     # recovery invisible
    assert rep.metrics.fault_counts() == {"STATE_FAULT": 1}
    faults = [e for e in tracer.events() if e["name"] == "fault"]
    assert len(faults) == 1
    # exact (step, slot) attribution survives the cross-shard fold
    assert faults[0]["args"]["slot"] == 0
    assert faults[0]["args"]["step"] == 1
    assert faults[0]["args"]["code"] & int(ErrorCode.STATE_FAULT)
    # the reconciliation fans out to every shard lane in the trace
    fanouts = [e for e in tracer.events() if e["name"] == "shard_fanout"]
    assert sorted(e["args"]["shard"] for e in fanouts) == list(range(TP))
    assert all(e["tid"] == SHARD_TID + e["args"]["shard"] for e in fanouts)
    assert postmortem.validate(merge_traces(tracer)) == []


def test_tp_paged_page_fault_reclaim_bit_exact(env):
    """A PAGE_FAULT word injected on one shard of the paged TP engine drives
    the page-reclaim lane exactly like the single-device engine: ledger
    repaired, streams bit-exact."""
    kw = dict(paged=True, page_size=8)
    clean = _streams(_serve_all(_replica(env, TP, config_kw=kw),
                                _requests(3)))
    rep = _replica(env, TP, config_kw=kw,
                   fault_injector=_shard_injector(
                       1, int(ErrorCode.PAGE_FAULT)))
    got = _serve_all(rep, _requests(3))
    assert _streams(got) == clean
    # one fault record + the page-reclaim ledger record riding alongside it
    # (same double entry the single-device paged engine makes)
    assert rep.metrics.fault_counts().get("PAGE_FAULT") == 2
    assert any(f.action == "page_reclaim" for f in rep.metrics.faults)
    rep.alloc.check()                                 # ledger intact


def test_tp_missing_fanout_is_a_postmortem_problem():
    """The post-mortem's TP rule: a shard_fanout group that does not cover
    every shard of its (pid, window) key is flagged."""
    tr = Tracer(pid=0)
    tr.instant("shard_fanout", "shard", tid=SHARD_TID, shard=0, tp=2,
               window=3, code=1)
    probs = postmortem.validate(merge_traces(tr))
    assert any("shard" in p for p in probs), probs


# ------------------------------------------------------------------ shard loss
def test_shard_loss_shrinks_group_with_zero_drops(env):
    """kind="shard_kill": one shard of a TP replica dies → the whole replica
    is a RANK_FAILED hard fault → ULFM shrink + ledger re-route; every
    accepted request is still answered OK, and the trace chains the shard
    loss to the replica kill."""
    cfg, _ = env
    group = ServeGroup(cfg, 2, config=_config(TP, max_len=48, trace=True))
    faults = FaultSchedule(
        [FaultSpec(step=1, kind="shard_kill", rank=1, shard=1)])
    out = group.serve(_requests(6, max_new=6, prompt_len=4), faults=faults)
    assert set(out.responses) == set(range(6))        # zero drops
    assert all(r.status == OK for r in out.responses.values())
    assert out.rerouted                               # dead rank's work moved
    trace = out.trace()
    events = {e["name"] for e in trace["traceEvents"]}
    assert {"shard_loss", "replica_kill", "ulfm_shrink", "reroute"} <= events
    loss = next(e for e in trace["traceEvents"] if e["name"] == "shard_loss")
    assert loss["args"]["shard"] == 1 and loss["args"]["tp"] == TP
    assert postmortem.validate(trace) == []


# --------------------------------------------------------------- corpus replay
_CORPUS = sorted((pathlib.Path(__file__).parent / "fuzz_corpus")
                 .glob("seed_overlap_0_*.json"))


@pytest.mark.parametrize("path", _CORPUS, ids=lambda p: p.stem)
def test_fuzz_corpus_replays_on_tp_kit(path):
    """The promoted overlap-engine corpus re-targeted at the TP kit must pass
    every oracle: completeness, bit-exactness vs the TP clean reference,
    page/trace invariants, no wedge."""
    from repro.fuzz import load_entry, run_trajectory

    traj = dataclasses.replace(load_entry(str(path))["trajectory"],
                               engine="overlap_tp")
    res = run_trajectory(traj)
    assert res.violations == [], res.violations


def test_fuzz_shard_targeted_op_round_trips_and_runs():
    from repro.fuzz import Op, Trajectory, run_trajectory

    traj = Trajectory(seed=5, engine="overlap_tp", n_requests=2, max_new=6,
                      ops=(Op("word", cycle=2, slot=0, step=1,
                              code=int(ErrorCode.STATE_FAULT), shard=1),))
    assert Trajectory.loads(traj.dumps()) == traj
    res = run_trajectory(traj)
    assert res.violations == []
    assert ("STATE_FAULT", "restore_good", "overlap_tp") in res.cells
    with pytest.raises(ValueError, match="non-TP engine"):
        Trajectory(seed=0, engine="overlap",
                   ops=(Op("word", cycle=1, code=1, shard=0),))


# ----------------------------------------------------------------- EngineConfig
class TestEngineConfig:
    def test_cross_field_validation(self):
        with pytest.raises(ValueError, match="tp>1 requires window"):
            EngineConfig(tp=2)
        with pytest.raises(ValueError, match="tp>1 requires overlap"):
            EngineConfig(tp=2, window=4, overlap=False)
        with pytest.raises(ValueError, match="paged=True requires window"):
            EngineConfig(paged=True)
        with pytest.raises(ValueError, match="speculate=True requires "
                                             "overlap"):
            EngineConfig(speculate=True, window=4, overlap=False)
        with pytest.raises(ValueError, match="tp must be"):
            EngineConfig(tp=0)

    def test_from_flags(self):
        c = EngineConfig.from_flags("win=8,spec=1,dlen=3,tp=2,page=16")
        assert (c.window, c.speculate, c.draft_len, c.tp) == (8, True, 3, 2)
        assert c.paged and c.page_size == 16          # page= implies paged
        assert EngineConfig.from_flags("paged,win=4").paged is True
        assert EngineConfig.from_flags("", num_slots=7).num_slots == 7
        # overrides beat the flag string
        assert EngineConfig.from_flags("slots=2", num_slots=5).num_slots == 5
        with pytest.raises(ValueError, match="unknown engine flag"):
            EngineConfig.from_flags("wnidow=8")

    def test_legacy_kwargs_are_hard_type_errors(self, env):
        # the one-release deprecation shim is gone: engine-shape kwargs on
        # the owners are plain TypeErrors now — config=EngineConfig(...) is
        # the only construction path
        cfg, params = env
        with pytest.raises(TypeError, match="num_slots"):
            Replica(cfg, params=params, num_slots=2, max_len=32, window=4)
        with pytest.raises(TypeError, match="max_len"):
            ServeGroup(cfg, 2, max_len=32)
        # no DeprecationWarning path remains anywhere in construction
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            rep = Replica(cfg, params=params,
                          config=EngineConfig(num_slots=2, max_len=32,
                                              window=4))
        assert rep.config.window == 4 and rep.config.num_slots == 2

    def test_unknown_kwarg_still_a_type_error(self, env):
        cfg, params = env
        with pytest.raises(TypeError, match="num_slotz"):
            Replica(cfg, params=params, num_slotz=2)

    def test_config_is_the_construction_path(self, env):
        c = _config(1, num_slots=3, max_len=32)
        rep = _replica(env, 1, config_kw=dict(num_slots=3, max_len=32))
        assert rep.config == c
        assert rep.sched.num_slots == 3 and rep.max_len == 32

    def test_tp_needs_devices(self, env):
        with pytest.raises(ValueError, match="devices"):
            _replica(env, 64)


# ------------------------------------------------------------------ strip_codes
def test_strip_codes_shared_helper():
    """One ignore-mask implementation serves DeviceFuture.fault_steps and the
    window enumeration (and the TP fold): attribution-only bits are stripped,
    words that carried only them zero out, and ignore=0 is the identity."""
    words = np.array([int(ErrorCode.DRAFT_REJECT),
                      int(ErrorCode.STATE_FAULT) | int(ErrorCode.DRAFT_REJECT),
                      0], np.uint32)
    got = np.asarray(strip_codes(words, int(ErrorCode.DRAFT_REJECT)))
    assert got.tolist() == [0, int(ErrorCode.STATE_FAULT), 0]
    assert strip_codes(words, 0) is words
