"""repro.serve: queue admission/deadlines, scheduler backfill, per-sequence
LFLR recovery, and ServeGroup shrink + re-route under a replica kill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.errors import ErrorCode
from repro.core.faults import FaultSchedule, FaultSpec
from repro.launch.steps import make_cache_prefill, make_slot_decode_step
from repro.models import build_model
from repro.serve import (
    EXPIRED,
    FAILED,
    OK,
    REJECTED,
    AdmissionPolicy,
    ContinuousBatchingScheduler,
    EngineConfig,
    Replica,
    Request,
    RequestQueue,
    ServeGroup,
)
from repro.serve.replica import SERVE_PROBES


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt


# ------------------------------------------------------------------ queue
def test_admission_rejects_on_full_queue():
    q = RequestQueue(AdmissionPolicy(max_queue=2), clock=FakeClock())
    assert q.submit(Request(id=0, prompt=(1,))) is None
    assert q.submit(Request(id=1, prompt=(1,))) is None
    resp = q.submit(Request(id=2, prompt=(1,)))
    assert resp is not None and resp.status == REJECTED
    assert "queue full" in resp.detail
    assert len(q) == 2


def test_admission_rejects_oversized_request():
    q = RequestQueue(AdmissionPolicy(max_total_len=8), clock=FakeClock())
    resp = q.submit(Request(id=0, prompt=(1, 2, 3, 4, 5, 6), max_new_tokens=4))
    assert resp is not None and resp.status == REJECTED
    assert q.submit(Request(id=1, prompt=(1, 2, 3), max_new_tokens=4)) is None


def test_queue_pops_earliest_deadline_first():
    clk = FakeClock()
    q = RequestQueue(clock=clk)
    q.submit(Request(id=0, prompt=(1,), deadline=None))
    q.submit(Request(id=1, prompt=(1,), deadline=10.0))
    q.submit(Request(id=2, prompt=(1,), deadline=5.0))
    assert [q.pop().id for _ in range(3)] == [2, 1, 0]
    assert q.pop() is None


def test_queue_expires_requests_past_deadline():
    clk = FakeClock()
    q = RequestQueue(clock=clk)
    q.submit(Request(id=0, prompt=(1,), deadline=2.0))
    q.submit(Request(id=1, prompt=(1,), deadline=50.0))
    clk.tick(3.0)
    got = q.pop()                       # skips the expired one
    assert got is not None and got.id == 1
    assert [r.id for r in q.drain_expired()] == [0]
    assert len(q) == 0


# -------------------------------------------------------------- scheduler
def _sched(n_reqs, num_slots=2, max_new=2, deadline=None):
    clk = FakeClock()
    q = RequestQueue(clock=clk)
    for i in range(n_reqs):
        assert q.submit(Request(id=i, prompt=(10 + i,), max_new_tokens=max_new,
                                deadline=deadline)) is None
    return ContinuousBatchingScheduler(num_slots, q, replica=7, clock=clk), clk


def test_scheduler_backfills_freed_slot_after_eviction():
    sched, clk = _sched(3, num_slots=2, max_new=2)
    admitted = sched.backfill()
    assert [(s, r.id) for s, r in admitted] == [(0, 0), (1, 1)]
    assert sched.free_slots() == []          # request 2 must wait
    # finish slot 0 (max_new=2) while slot 1 is mid-flight
    assert sched.commit_token(0, 100) is None
    resp = sched.commit_token(0, 101)
    assert resp is not None and resp.status == OK and resp.tokens == (100, 101)
    assert resp.replica == 7
    assert sched.commit_token(1, 200) is None
    # the freed slot is backfilled with the waiting request
    admitted = sched.backfill()
    assert [(s, r.id) for s, r in admitted] == [(0, 2)]
    tokens, pos = sched.step_inputs()
    assert tokens[1, 0, 0] == 200
    assert pos[1] == 1 + 1 - 1               # prompt_len + generated - 1


def test_scheduler_expires_active_sequence_mid_decode():
    sched, clk = _sched(1, num_slots=1, max_new=10, deadline=2.5)
    sched.backfill()
    sched.commit_token(0, 5)
    clk.tick(3.0)
    out = sched.expire_active()
    assert len(out) == 1 and out[0].status == EXPIRED
    assert out[0].tokens == (5,)             # partial progress reported
    assert sched.free_slots() == [0]


def test_scheduler_drain_in_flight_for_reroute():
    sched, _ = _sched(2, num_slots=2, max_new=4)
    sched.backfill()
    sched.commit_token(0, 1)
    reqs = sched.drain_in_flight()
    assert sorted(r.id for r in reqs) == [0, 1]
    assert not sched.has_active()


# ---------------------------------------------------------------- replica
@pytest.fixture(scope="module")
def serve_env():
    cfg = smoke_config("recurrentgemma-2b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    decode_fn = jax.jit(make_slot_decode_step(cfg, SERVE_PROBES))
    prefill_fn = make_cache_prefill(cfg, SERVE_PROBES)
    return cfg, params, decode_fn, prefill_fn


def _replica(env, **kw):
    cfg, params, decode_fn, prefill_fn = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", 48)
    return Replica(cfg, params=params, config=EngineConfig(**conf),
                   decode_fn=decode_fn, prefill_fn=prefill_fn, **kw)


def _serve_all(rep, reqs, inject_at=None):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps = [], 0
    while not rep.idle():
        if inject_at is not None and steps == inject_at:
            assert rep.inject_state_fault(0) == 0
        out.extend(rep.step())
        steps += 1
        assert steps < 1000
    return {r.id: r for r in out}


def _requests(n, max_new=6):
    return [Request(id=i, prompt=(10 + i, 20 + i, 30 + i), max_new_tokens=max_new)
            for i in range(n)]


def test_replica_serves_with_continuous_backfill(serve_env):
    rep = _replica(serve_env)
    out = _serve_all(rep, _requests(3, max_new=4))
    assert sorted(out) == [0, 1, 2]
    assert all(r.status == OK and len(r.tokens) == 4 for r in out.values())
    # request 2 only got a slot after an eviction: strictly later first token
    assert out[2].ttft_s > out[0].ttft_s and out[2].ttft_s > out[1].ttft_s
    s = rep.metrics.summary()
    assert s["statuses"] == {OK: 3} and s["faults"] == {}


def test_replica_lflr_reprefill_on_state_fault(serve_env):
    clean = _serve_all(_replica(serve_env), _requests(2))
    rep = _replica(serve_env)
    faulty = _serve_all(rep, _requests(2), inject_at=3)
    # the paper's contract: the fault became an exception and was recovered —
    # recompute (LFLR), not restart, so the trajectory is bit-identical
    assert faulty[0].status == OK and faulty[0].retries == 1
    assert faulty[0].tokens == clean[0].tokens
    # per-sequence: the co-batched sequence never noticed
    assert faulty[1].status == OK and faulty[1].retries == 0
    assert faulty[1].tokens == clean[1].tokens
    assert rep.metrics.fault_counts().get("STATE_FAULT") == 1
    log = rep.metrics.to_event_log()
    assert len(log.faults()) >= 1


def test_replica_fails_unrecoverable_request_without_wedging(serve_env):
    rep = _replica(serve_env, max_request_retries=1)
    doomed_mark = 499
    orig = rep._prefill

    def cursed_prefill(params, tokens, max_len, start_pos=0):
        logits, cache, word = orig(params, tokens, max_len, start_pos)
        if int(tokens[0][0]) == doomed_mark:   # this request always re-faults
            word = word | jnp.uint32(int(ErrorCode.STATE_FAULT))
        return logits, cache, word

    rep._prefill = cursed_prefill
    out = _serve_all(rep, [
        Request(id=0, prompt=(doomed_mark, 2, 3), max_new_tokens=4),
        Request(id=1, prompt=(7, 8, 9), max_new_tokens=4),
    ])
    assert out[0].status == FAILED and out[0].retries == 2
    assert out[1].status == OK and len(out[1].tokens) == 4


def test_replica_expires_deadline_in_queue_and_mid_decode(serve_env):
    clk = FakeClock()
    rep = _replica(serve_env, num_slots=2, clock=clk)
    # slots are taken by two long requests; the third expires while queued
    assert rep.submit(Request(id=0, prompt=(1, 2), max_new_tokens=8,
                              deadline=4.0)) is None
    assert rep.submit(Request(id=1, prompt=(3, 4), max_new_tokens=8)) is None
    assert rep.submit(Request(id=2, prompt=(5, 6), max_new_tokens=2,
                              deadline=1.0)) is None
    out = {}
    for _ in range(12):
        clk.tick(1.0)
        out.update({r.id: r for r in rep.step()})
    assert out[2].status == EXPIRED and out[2].tokens == ()
    assert out[0].status == EXPIRED and len(out[0].tokens) >= 1   # mid-decode
    assert out[1].status == OK and len(out[1].tokens) == 8


def test_replica_stops_at_eos(serve_env):
    # learn which token greedy decode emits, then declare it EOS
    free = _serve_all(_replica(serve_env), _requests(1, max_new=4))
    eos = free[0].tokens[0]
    rep = _replica(serve_env, eos_id=eos)
    out = _serve_all(rep, _requests(1, max_new=4))
    assert out[0].status == OK and out[0].tokens == (eos,)


def test_slot_decode_matches_single_sequence_prefill(serve_env):
    """The vmapped per-slot step must agree with the plain decode path."""
    cfg, params, decode_fn, prefill_fn = serve_env
    prompt = (11, 22, 33)
    rep = _replica(serve_env)
    out = _serve_all(rep, [Request(id=0, prompt=prompt, max_new_tokens=3)])
    # replay the whole sequence through the non-vmapped prefill path
    logits, _, word = prefill_fn(
        params, np.asarray([list(prompt) + list(out[0].tokens[:-1])], np.int32),
        48)
    assert int(word) == 0
    assert int(np.argmax(np.asarray(logits)[0, -1])) == out[0].tokens[-1]


# -------------------------------------------------------------- ServeGroup
@pytest.fixture(scope="module")
def group():
    cfg = smoke_config("recurrentgemma-2b")
    return ServeGroup(cfg, 3, config=EngineConfig(num_slots=2, max_len=48))


def test_group_survives_replica_kill_with_zero_dropped_requests(group):
    reqs = [Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=5)
            for i in range(9)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=1)]))
    assert [r.rank for r in res.reports if r.killed] == [1]
    # zero dropped: every accepted request got a terminal OK answer
    assert sorted(res.responses) == list(range(9))
    assert all(r.ok for r in res.responses.values())
    # the dead replica's unanswered requests were re-routed, not lost
    assert set(res.rerouted) and set(res.rerouted) <= set(range(9))
    for rank in (0, 2):
        report = res.report(rank)
        assert report is not None, res.reports[rank].exception
        shrinks = [e for e in report.events if e[0] == "shrink"]
        assert len(shrinks) == 1 and shrinks[0][2] == 2      # world 3 -> 2
    # answered by survivors only
    assert {r.replica for r in res.responses.values()} <= {0, 2}


def test_group_soft_fault_stays_local_and_everyone_answers(group):
    reqs = [Request(id=i, prompt=(40 + i, 41 + i), max_new_tokens=5)
            for i in range(6)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="state_nan", rank=0)]))
    assert sorted(res.responses) == list(range(6))
    assert all(r.ok for r in res.responses.values())
    assert res.rerouted == ()
    r0 = res.report(0)
    assert r0 is not None
    assert [e for e in r0.events if e[0] == "inject"]
    assert r0.metrics.fault_counts().get("STATE_FAULT") == 1
    # no shrink happened anywhere: soft faults are replica-local
    for rank in range(3):
        assert not [e for e in res.report(rank).events if e[0] == "shrink"]
