"""Paged KV cache with fault-scoped page ownership (ISSUE 4).

The paged engine (``Replica(window=K, paged=True)``) pools full-attention
KV into a shared page pool addressed through a device-resident page table.
Contracts fenced here:

* token-bit-exactness vs the contiguous overlap engine on identical traffic,
  steady and faulted (the gathered view is bit-equal to the contiguous
  cache, so greedy trajectories cannot diverge);
* LFLR page reclaim is fault-scoped: recovering one lane frees + re-acquires
  *its* pages only — co-slot pages are untouched and co-slot streams
  bit-exact;
* pool exhaustion preempts the oldest lane back into the queue (zero dropped
  requests) and the ledger stays consistent;
* page-table corruption surfaces in-band as ``PAGE_FAULT`` at the wait and
  the LFLR re-queue repairs the mapping;
* the paged chunked prefill chain reproduces the contiguous prefill bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.errors import ErrorCode
from repro.launch.paging import PagedLayout, pages_for
from repro.launch.steps import make_cache_prefill, make_chunked_prefill
from repro.models import build_model
from repro.serve import OK, EngineConfig, Replica, Request
from repro.serve.replica import SERVE_PROBES

MAX_LEN = 32
PAGE = 8
WINDOW = 4


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("qwen3-1.7b")     # pure full attention: all KV paged
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _replica(env, *, paged, **kw):
    cfg, params = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", MAX_LEN)
    conf.setdefault("window", WINDOW)
    conf.setdefault("max_request_retries", 4)
    conf.setdefault("page_size", PAGE)
    return Replica(cfg, params=params,
                   config=EngineConfig(paged=paged, **conf), **kw)


def _requests(n, max_new=8, prompt_len=5):
    return [Request(id=i, prompt=tuple(10 + i + j for j in range(prompt_len)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_all(rep, reqs, inject_at=None, inject_slot=None, hook=None):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps = {}, 0
    while not rep.idle():
        if inject_at is not None and steps == inject_at:
            slot = inject_slot
            if slot is None:             # first decoding lane both engines run
                decoding = [i for i in rep.sched.active_slots()
                            if rep.sched.slots[i].pending is None]
                slot = decoding[0] if decoding else None
            if slot is not None:
                assert rep.inject_state_fault(slot) == slot
        if hook is not None:
            hook(rep, steps)
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 2000
    return out


# --------------------------------------------------------------- bit-exactness
@pytest.mark.parametrize("label,inject_at", [("steady", None), ("faulted", 6)])
def test_paged_bit_exact_vs_contiguous(env, label, inject_at):
    """Same traffic, same injections: the paged engine's token streams must
    equal the contiguous overlap engine's exactly, with zero host stalls and
    a consistent ledger afterwards."""
    base = _serve_all(_replica(env, paged=False), _requests(5),
                      inject_at=inject_at)
    rep = _replica(env, paged=True)
    got = _serve_all(rep, _requests(5), inject_at=inject_at)
    assert sorted(got) == sorted(base)
    for i in base:
        assert got[i].status == base[i].status == OK
        assert got[i].tokens == base[i].tokens, (label, i)
    m = rep.metrics.summary()
    assert m["host_stalls"] == 0 and m["prefills"] == 0
    assert m["pages_allocated"] > 0
    assert m["pages_allocated"] == m["pages_freed"]   # all reclaimed at drain
    rep.alloc.check()


def test_paged_blocking_engine_bit_exact(env):
    """overlap=False: the blocking paged prefill (pool writes through the
    page table, in-program scrub) reproduces the contiguous streams too."""
    base = _serve_all(_replica(env, paged=False, overlap=False), _requests(4))
    rep = _replica(env, paged=True, overlap=False)
    got = _serve_all(rep, _requests(4))
    for i in base:
        assert got[i].status == OK
        assert got[i].tokens == base[i].tokens
    assert rep.metrics.prefills == 4     # blocking engine prefills per lane
    rep.alloc.check()


def test_paged_chunked_prefill_chain_matches_contiguous(env):
    """Chaining paged chunks through the pool is bit-identical to the
    contiguous fused prefill: same logits, and the gathered view equals the
    contiguous cache leaf-for-leaf."""
    cfg, params = env
    layout = PagedLayout(build_model(cfg).init_cache(1, MAX_LEN), MAX_LEN,
                         page_size=PAGE, num_pages=8)
    assert layout.has_paged_leaves
    full = make_cache_prefill(cfg, SERVE_PROBES, fused=True)
    chunked = make_chunked_prefill(cfg, SERVE_PROBES, chunk=4, paged=layout)
    prompt = tuple(range(3, 14))
    l_ref, c_ref, w_ref = full(params, np.asarray([prompt], np.int32), MAX_LEN)

    hybrid = layout.init_hybrid(build_model(cfg).init_cache(1, MAX_LEN), 2)
    table = layout.empty_table(2)
    slot = 1
    n_pages = pages_for(len(prompt) + 1, PAGE)
    table[slot, :n_pages] = np.arange(2, 2 + n_pages)    # arbitrary phys ids
    row = jnp.asarray(table[slot])
    word = jnp.uint32(0)
    logits = None
    for lo in range(0, len(prompt), 4):
        part = prompt[lo:lo + 4]
        padded = np.zeros((1, 4), np.int32)
        padded[0, :len(part)] = part
        logits, hybrid, w = chunked(params, hybrid, row, jnp.int32(slot),
                                    padded, jnp.int32(len(part)),
                                    jnp.int32(lo))
        word = word | w
    assert int(word) == int(w_ref) == 0
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(l_ref))
    view = layout.gather_slot(hybrid, row, jnp.int32(slot))
    for a, b in zip(jax.tree_util.tree_leaves(view),
                    jax.tree_util.tree_leaves(c_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- fault-scoped reclaim
def test_lflr_page_reclaim_leaves_coslot_pages_untouched(env):
    """A faulted lane frees and re-acquires *its own* pages; the co-batched
    slot's physical pages never move and its stream is bit-exact vs an
    undisturbed run — recovery is scoped to the smallest recoverable unit,
    now including memory ownership."""
    reqs = lambda: [Request(id=0, prompt=(3, 5, 7), max_new_tokens=24),  # noqa: E731
                    Request(id=1, prompt=tuple(range(20, 26)),
                            max_new_tokens=20)]
    clean = _serve_all(_replica(env, paged=False), reqs())

    rep = _replica(env, paged=True)
    snap = {}

    def hook(r, steps):
        s0, s1 = r.sched.slots[0], r.sched.slots[1]
        if ("s0" not in snap and steps >= 3
                and s0.active and s0.pending is None
                and s1.active and s1.pending is None):
            # both lanes decoding: snapshot ownership, poison slot 1
            snap["s0"] = r.alloc.owned(0)
            snap["s1"] = r.alloc.owned(1)
            assert snap["s0"] and snap["s1"]
            assert r.inject_state_fault(1) == 1
        elif "s0" in snap and r.sched.slots[0].active \
                and r.sched.slots[0].req.id == 0:
            # every step through detection + recovery: slot 0's physical
            # pages never move (reclaim is scoped to the faulted lane)
            assert r.alloc.owned(0)[:len(snap["s0"])] == snap["s0"]
            assert np.array_equal(
                r.page_table[0, :len(snap["s0"])], snap["s0"])
            snap["checked"] = True

    got = _serve_all(rep, reqs(), hook=hook)
    assert snap.get("checked"), "post-recovery ownership was never checked"
    assert got[1].status == OK and got[1].retries == 1
    assert got[0].status == OK and got[0].retries == 0
    for i in clean:
        assert got[i].tokens == clean[i].tokens
    assert rep.metrics.summary()["host_stalls"] == 0
    rep.alloc.check()


# ------------------------------------------------------ exhaustion / eviction
def test_pool_exhaustion_evicts_oldest_drops_nothing(env):
    """A pool half the size the slots could demand: growth under load must
    preempt lanes (oldest first) back into the queue instead of dropping or
    wedging — every request still gets an OK answer and the tokens match an
    unpressured run."""
    base = _serve_all(_replica(env, paged=False, max_len=16), _requests(
        6, max_new=8, prompt_len=5))
    rep = _replica(env, paged=True, max_len=16, page_size=4, page_budget=5)
    got = _serve_all(rep, _requests(6, max_new=8, prompt_len=5))
    assert sorted(got) == sorted(base)
    for i in base:
        assert got[i].status == OK
        assert got[i].tokens == base[i].tokens, i
    m = rep.metrics.summary()
    assert m["page_evictions"] > 0, "pressure never triggered an eviction"
    assert m["peak_pages_in_use"] <= 5
    rep.alloc.check()


def test_scrub_staging_survives_eviction_recycled_ids(env):
    """Regression: growth inside one pre-dispatch prepare can evict a lane
    and immediately recycle its freed pages, so the raw new-id list exceeds
    ``num_pages`` (the same physical id granted twice). The fixed-size scrub
    staging buffer must dedupe rather than crash mid-step — exactly under
    the pool pressure the eviction path exists to survive."""
    rep = _replica(env, paged=True, num_slots=4, max_len=32, page_size=4,
                   page_budget=6, window=8)
    got = _serve_all(rep, _requests(6, max_new=6, prompt_len=5))
    assert sorted(got) == list(range(6))
    assert all(r.status == OK for r in got.values())
    assert rep.metrics.summary()["page_evictions"] > 0
    rep.alloc.check()


def test_watermark_gates_admission(env):
    """With a watermark the scheduler defers admission while headroom is
    thin instead of thrashing: requests still all complete, and concurrency
    stays within what the pool can grow."""
    rep = _replica(env, paged=True, max_len=16, page_size=4, page_budget=5,
                   page_watermark=1)
    got = _serve_all(rep, _requests(5, max_new=6, prompt_len=5))
    assert all(r.status == OK for r in got.values())
    rep.alloc.check()


def test_pool_smaller_than_max_len_cannot_livelock(env):
    """Regression: with a pool smaller than ``max_len`` (admission clamps to
    pool capacity), window over-decode used to push the growth target past
    what the pool can ever hold — the lane evicted the fleet, self-evicted,
    requeued and replayed forever. Growth and the page probe now clamp to
    pool capacity, so a legally admitted request always completes."""
    rep = _replica(env, paged=True, num_slots=1, max_len=64, page_size=16,
                   page_budget=3)          # pool = 48 positions < max_len
    req = Request(id=0, prompt=tuple(3 + j for j in range(20)),
                  max_new_tokens=24)       # total 44 <= 48: must be admitted
    assert rep.submit(req) is None
    got = _serve_all(rep, [])
    assert got[0].status == OK and len(got[0].tokens) == 24
    assert rep.metrics.summary()["page_evictions"] == 0
    rep.alloc.check()


def test_request_larger_than_pool_rejected_at_submit(env):
    """A request that could never fit in the pool must be REJECTED at
    admission, not deferred forever by the watermark gate."""
    rep = _replica(env, paged=True, max_len=32, page_size=8, page_budget=2)
    resp = rep.submit(Request(id=0, prompt=tuple(range(3, 21)),
                              max_new_tokens=8))     # 26 tokens > 16 capacity
    assert resp is not None and resp.status == "rejected"


# --------------------------------------------------------- in-band PAGE_FAULT
def test_page_table_corruption_raises_page_fault_and_recovers(env):
    """Unmapping a decoding lane's table row behind the allocator's back is
    ledger corruption: the in-band probe latches PAGE_FAULT, the wait raises,
    and the LFLR re-queue (free + re-acquire + scrub) rebuilds the mapping —
    the request still completes with the exact clean trajectory."""
    clean = _serve_all(_replica(env, paged=False), _requests(2, max_new=16))

    rep = _replica(env, paged=True)
    state = {}

    def hook(r, steps):
        s0 = r.sched.slots[0]
        if ("corrupted" not in state and steps >= 4
                and s0.active and s0.pending is None):
            r.page_table[0, :] = r.layout.sentinel    # device table corrupted
            state["corrupted"] = True

    got = _serve_all(rep, _requests(2, max_new=16), hook=hook)
    assert state.get("corrupted")
    for i in clean:
        assert got[i].status == OK
        assert got[i].tokens == clean[i].tokens, i
    counts = rep.metrics.fault_counts()
    assert counts.get("PAGE_FAULT", 0) >= 1, counts
    assert any(f.action == "page_reclaim" for f in rep.metrics.faults)
    rep.alloc.check()


def test_page_probe_word(env):
    cfg, _ = env
    layout = PagedLayout(build_model(cfg).init_cache(1, MAX_LEN), MAX_LEN,
                         page_size=PAGE, num_pages=4)
    table = jnp.asarray([[0, 1, layout.sentinel, layout.sentinel],
                         [layout.sentinel, 2, 3, 1],
                         [0, layout.sentinel, 2, 3]], jnp.int32)
    word = layout.probe(table, jnp.asarray([9, 1, 20], jnp.int32))
    # slot 0 writes pos 9 → pages 0..1 mapped: clean (trailing sentinels are
    # beyond the live region and must not trip)
    assert int(word[0]) == 0
    # slot 1 writes pos 1 → logical page 0 → sentinel: PAGE_FAULT
    assert int(word[1]) == int(ErrorCode.PAGE_FAULT)
    # slot 2 writes pos 20 (page 2, mapped) but READ page 1 is unmapped —
    # silent zero-reads must surface too
    assert int(word[2]) == int(ErrorCode.PAGE_FAULT)


def test_paged_degenerates_cleanly_without_pageable_leaves():
    """A hybrid arch (sliding-window rings + recurrent state, nothing with
    capacity == max_len) has no pageable leaves: paged=True must serve
    bit-identically to the contiguous engine with an idle ledger rather
    than wedging or misclassifying ring buffers as pages."""
    cfg = smoke_config("recurrentgemma-2b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def serve(paged):
        rep = Replica(cfg, params=params,
                      config=EngineConfig(num_slots=2, max_len=MAX_LEN,
                                          window=WINDOW, paged=paged,
                                          page_size=PAGE))
        return rep, _serve_all(rep, _requests(3))

    _, base = serve(False)
    rep, got = serve(True)
    assert not rep.layout.has_paged_leaves
    for i in base:
        assert got[i].status == OK and got[i].tokens == base[i].tokens
    assert rep.metrics.summary()["pages_allocated"] == 0


# -------------------------------------------------------------- paged fleet
def test_paged_group_kill_zero_dropped_requests(env):
    """The PR-1 hard-fault contract survives paging: a replica kill
    mid-serve shrinks the group and re-routes; survivors' page pools answer
    every request (each replica owns its own pool, the layout and jitted
    programs are shared)."""
    from repro.core.faults import FaultSchedule, FaultSpec
    from repro.serve import ServeGroup

    cfg, _ = env
    group = ServeGroup(cfg, 3,
                       config=EngineConfig(num_slots=2, max_len=MAX_LEN,
                                           window=WINDOW, paged=True,
                                           page_size=PAGE))
    reqs = [Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=6)
            for i in range(9)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=1)]))
    assert [r.rank for r in res.reports if r.killed] == [1]
    assert sorted(res.responses) == list(range(9))
    assert all(r.ok for r in res.responses.values())
    assert {r.replica for r in res.responses.values()} <= {0, 2}


# ----------------------------------------------------------- layout mechanics
def test_layout_classification_and_budget(env):
    cfg, _ = env
    one = build_model(cfg).init_cache(1, MAX_LEN)
    layout = PagedLayout(one, MAX_LEN, page_size=PAGE, num_pages=8)
    # qwen3 is pure full attention: every KV leaf paged, nothing dense
    n_leaves = len(jax.tree_util.tree_leaves(one))
    assert layout.has_paged_leaves
    assert layout.max_pages == MAX_LEN // PAGE
    assert layout.pool_bytes() == 8 * layout.page_bytes()
    assert (layout.contiguous_paged_bytes_per_slot()
            == layout.max_pages * layout.page_bytes())
    hybrid = layout.init_hybrid(one, 3)
    assert len(jax.tree_util.tree_leaves(hybrid)) == n_leaves
    # hybrid layers: paged leaves lead with num_pages, not num_slots
    for (path, leaf) in jax.tree_util.tree_flatten_with_path(hybrid)[0]:
        if layout.is_paged_path(path):
            assert leaf.shape[0] == 8
    with pytest.raises(ValueError, match="multiple"):
        PagedLayout(one, MAX_LEN, page_size=5, num_pages=8)


def test_paged_requires_window_mode(env):
    from repro.serve import ServeGroup

    cfg, _ = env
    with pytest.raises(ValueError, match="window"):
        _replica(env, paged=True, window=0)
    # the group must fail at construction too, not as N thread deaths later
    with pytest.raises(ValueError, match="window"):
        ServeGroup(cfg, 2, config=EngineConfig(paged=True, window=0))


def test_oversized_watermark_request_still_served(env):
    """A request so large that pages + watermark exceed the pool can never
    pass the gated admission check — the headroom must be waived (admit when
    it plainly fits) or an accepted request would be deferred forever."""
    rep = _replica(env, paged=True, num_slots=1, max_len=64, page_size=16,
                   page_budget=4, page_watermark=1)
    req = Request(id=0, prompt=tuple(3 + j for j in range(50)),
                  max_new_tokens=8)     # needs 4 pages; 4+1 > pool of 4
    assert rep.submit(req) is None      # fits the pool outright: accepted
    got = _serve_all(rep, [])
    assert got[0].status == OK and len(got[0].tokens) == 8
    rep.alloc.check()


def test_gather_of_unmapped_pages_reads_zero(env):
    """The fill-mode gather is the bit-exactness linchpin: an unassigned
    logical page must read as zeros (= fresh contiguous cache), and a lane
    with a sentinel row must scatter nowhere."""
    cfg, _ = env
    one = build_model(cfg).init_cache(1, MAX_LEN)
    layout = PagedLayout(one, MAX_LEN, page_size=PAGE, num_pages=4)
    hybrid = layout.init_hybrid(one, 2)
    # fill pool page 2 with ones; map slot 0 → [2, sentinel...], slot 1 unmapped
    hybrid = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), hybrid)
    table = layout.empty_table(2)
    table[0, 0] = 2
    views = layout.gather(hybrid, jnp.asarray(table))
    for leaf in jax.tree_util.tree_leaves(views):
        arr = np.asarray(leaf)
        cap_ax = arr.ndim - 3
        sl = [slice(None)] * arr.ndim
        sl[0], sl[cap_ax] = 0, slice(0, PAGE)
        assert np.all(arr[tuple(sl)] == 1)            # mapped page: content
        sl[cap_ax] = slice(PAGE, None)
        assert np.all(arr[tuple(sl)] == 0)            # unmapped: zeros
        assert np.all(arr[1] == 0)                    # whole slot unmapped
    # scatter through a sentinel row must drop every write
    poisoned = jax.tree_util.tree_map(lambda v: v + 7.0, views)
    back = layout.scatter(hybrid, poisoned, jnp.asarray(table))
    for old, new in zip(jax.tree_util.tree_leaves(hybrid),
                        jax.tree_util.tree_leaves(back)):
        o, n = np.asarray(old), np.asarray(new)
        assert np.all(n[3] == o[3])                   # page 3 never referenced
        assert np.all(n[2] == 8)                      # slot 0's mapped page
