"""ULFM protocol tests (paper §III-C): revoke/agree/shrink, hard-fault detection,
corrupted-communicator semantics, and recovery by shrinking."""
import pytest

from repro.core import (
    Comm,
    CommCorruptedError,
    ErrorCode,
    PropagatedError,
    RankFailedError,
    RevokedError,
    TimeoutError_,
    initialize,
    run_ranks,
)

T = 20.0


def _world(ctx):
    return initialize(ctx, default_timeout=T).comm_world()


def test_signal_error_via_revoke():
    """signal_error revokes; agree(1); shrink; enumeration — all ranks see it."""
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 0:
            with pytest.raises(PropagatedError) as ei:
                comm.signal_error(ErrorCode.USER)
        else:
            with pytest.raises(PropagatedError) as ei:
                comm.recv(src=0).wait()
        assert [(e.rank, e.code) for e in ei.value.errors] == [
            (0, int(ErrorCode.USER))]
        # after shrink the communicator is usable again (same membership)
        assert comm.size == 4
        comm.barrier()
        return "ok"

    res = run_ranks(4, fn, ulfm=True)
    for r in res:
        assert r.exception is None, r.exception
        assert r.value == "ok"


def test_hard_fault_detected_and_corrupts():
    """Rank death (node loss) ⇒ survivors throw CommCorruptedError (paper: hard
    failure implies corrupted communicator via agree=0)."""
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 2:
            ctx.die()  # hard fault: process never returns
        with pytest.raises(CommCorruptedError):
            comm.recv(src=2).wait()
        return "observed hard fault"

    res = run_ranks(3, fn, ulfm=True)
    assert res[2].killed
    for r in res[:2]:
        assert r.exception is None, r.exception
        assert r.value == "observed hard fault"


def test_shrink_recovery_after_hard_fault():
    """Paper use case 1 (LFLR): survivors shrink and continue with fewer ranks."""
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 1:
            ctx.die()
        with pytest.raises(CommCorruptedError):
            comm.recv(src=1).wait()
        comm.shrink_to_survivors()
        assert comm.size == 3
        # prove the shrunk communicator works: ring send/recv among survivors
        nxt = (comm.rank + 1) % comm.size
        prv = (comm.rank - 1) % comm.size
        fs = comm.send(comm.rank, dst=nxt)
        fr = comm.recv(src=prv)
        got = fr.wait()
        fs.wait()
        assert got == prv
        return comm.size

    res = run_ranks(4, fn, ulfm=True)
    assert res[1].killed
    for i in (0, 2, 3):
        assert res[i].exception is None, res[i].exception
        assert res[i].value == 3


def test_revoked_error_on_plain_op():
    """Operations on a revoked communicator fail with RevokedError at transport
    level (MPI_ERR_COMM_REVOKED)."""
    def fn(ctx):
        if ctx.rank == 0:
            ctx.revoke(ctx.world)
            return "revoked"
        # wait until the revocation lands, then try to use the world context
        import time
        for _ in range(100):
            if ctx.world.revoked:
                break
            time.sleep(0.01)
        with pytest.raises(RevokedError):
            ctx.isend(ctx.world, 0, 0, "x")
        return "saw revoked"

    res = run_ranks(2, fn, ulfm=True)
    for r in res:
        assert r.exception is None, r.exception


def test_corrupted_on_unwinding_ulfm():
    """Destructor-during-unwinding under ULFM: revoke + agree(0) ⇒ everyone
    throws CommCorruptedError."""
    def fn(ctx):
        inst = initialize(ctx, default_timeout=T)
        if ctx.rank == 0:
            with pytest.raises(RuntimeError):
                with inst.comm_world() as comm:
                    raise RuntimeError("boom")
            return "unwound"
        with inst.comm_world() as comm:
            with pytest.raises(CommCorruptedError):
                comm.recv(src=0).wait()
            return "corrupted observed"

    res = run_ranks(3, fn, ulfm=True)
    for r in res:
        assert r.exception is None, r.exception


def test_agree_is_fault_tolerant():
    """MPI_Comm_agree completes among survivors even when a rank dies mid-call."""
    def fn(ctx):
        if ctx.rank == 1:
            ctx.die()
        # survivors agree; the dead rank's contribution is excluded
        out = ctx.agree(ctx.world, 1, timeout=T)
        return out

    res = run_ranks(3, fn, ulfm=True)
    assert res[1].killed
    assert res[0].value == 1 and res[2].value == 1


def test_multiple_signallers_ulfm():
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank in (1, 2):
            with pytest.raises(PropagatedError) as ei:
                comm.signal_error(50 + comm.rank)
        else:
            with pytest.raises(PropagatedError) as ei:
                comm.recv(src=1).wait()
        return sorted((e.rank, e.code) for e in ei.value.errors)

    res = run_ranks(5, fn, ulfm=True)
    expected = [(1, 51), (2, 52)]
    for r in res:
        assert r.exception is None, r.exception
        assert r.value == expected
