"""Small-scale dry-run: the full lower→compile→analyse pipeline on an 8-device
host mesh (subprocess so the main pytest process keeps 1 device)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import input_specs, make_step_for
from repro.roofline.hlo import estimate_hbm_bytes, parse_collectives

ARCH = "%(arch)s"
cfg = smoke_config(ARCH).replace(dtype="bfloat16")
shape = ShapeConfig("%(kind)s_t", seq_len=64, global_batch=8, kind="%(kind)s")
mesh = make_host_mesh(data=4, model=2)
step = make_step_for(cfg, shape)
args, shardings = input_specs(cfg, shape, mesh)
with mesh:
    lowered = jax.jit(step, in_shardings=shardings).lower(*args)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
    cost = cost[0] if cost else {}
mem = compiled.memory_analysis()
hlo = compiled.as_text()
coll = parse_collectives(hlo)
hbm = estimate_hbm_bytes(hlo)
assert cost.get("flops", 0) > 0
assert hbm["total_bytes"] > 0
assert mem.argument_size_in_bytes > 0
print("CELL_OK", ARCH, cost["flops"], int(coll.total_bytes))
"""


def _run(arch: str, kind: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT % {"arch": arch, "kind": kind}],
        cwd="/root/repo", env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CELL_OK" in out.stdout


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "recurrentgemma-2b",
                                  "mamba2-2.7b", "hubert-xlarge"])
def test_small_mesh_train_cell(arch):
    _run(arch, "train")


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-2.7b"])
def test_small_mesh_decode_cell(arch):
    _run(arch, "decode")


def test_multipod_small_mesh():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import input_specs, make_step_for
cfg = smoke_config("qwen3-1.7b").replace(dtype="bfloat16")
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_host_mesh(data=2, model=2, pod=2)
step = make_step_for(cfg, shape)
args, shardings = input_specs(cfg, shape, mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
hlo = compiled.as_text()
assert "all-reduce" in hlo
print("MULTIPOD_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIPOD_OK" in out.stdout
