"""Property-based tests (hypothesis) for the protocol invariants the paper's
correctness rests on:

P1  agreement: after any set of simultaneous signallers, every rank observes the
    *identical, rank-ordered* (rank, code) table — black channel AND ULFM.
P2  deadlock preclusion: no rank blocks forever regardless of who signals while
    others wait.
P3  enumeration oracle: the device-channel shard-map port equals the pure-jnp
    oracle for arbitrary word vectors (covered at 8 devices in
    test_core_device_channel; here the jnp oracle itself is property-tested
    against a python reference).
P4  survivor consistency: any kill set under ULFM leaves all survivors with the
    same shrunk membership.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                    "(pip install repro[test])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (
    CommCorruptedError,
    PropagatedError,
    decode_table,
    enumerate_errors_ref,
    initialize,
    run_ranks,
)

T = 30.0


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_p1_agreement_blackchannel(data):
    nranks = data.draw(st.integers(2, 8), label="nranks")
    signallers = data.draw(
        st.dictionaries(st.integers(0, nranks - 1), st.integers(1, 1000),
                        min_size=1, max_size=nranks), label="signallers")

    def fn(ctx):
        comm = initialize(ctx, default_timeout=T).comm_world()
        try:
            if comm.rank in signallers:
                comm.signal_error(signallers[comm.rank])
            else:
                comm.recv(src=(comm.rank + 1) % comm.size).wait()
        except PropagatedError as e:
            return [(x.rank, x.code) for x in e.errors]
        return None

    res = run_ranks(nranks, fn, join_timeout=T * 3)
    expected = sorted((r, c) for r, c in signallers.items())
    for r in res:
        assert r.exception is None, (r.rank, r.exception)
        assert r.value == expected      # identical AND rank-ordered (P1)


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_p1_p4_ulfm_with_kills(data):
    nranks = data.draw(st.integers(3, 7), label="nranks")
    victim = data.draw(st.integers(1, nranks - 1), label="victim")

    def fn(ctx):
        comm = initialize(ctx, default_timeout=T).comm_world()
        if comm.rank == victim:
            ctx.die()
        try:
            comm.recv(src=victim).wait()
        except CommCorruptedError:
            comm.shrink_to_survivors()
            return comm.size
        return None

    res = run_ranks(nranks, fn, ulfm=True, join_timeout=T * 3)
    assert res[victim].killed
    sizes = {r.value for r in res if not r.killed and r.exception is None}
    assert sizes == {nranks - 1}        # all survivors agree (P4)
    assert all(r.exception is None for r in res if not r.killed)  # (P2)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=24))
def test_p3_enumeration_oracle(words):
    """jnp oracle vs straight-python reference for arbitrary word vectors."""
    arr = jnp.asarray(np.asarray(words, np.uint32))
    count, table = enumerate_errors_ref(arr, max_errors=8)
    got = [(e.rank, e.code) for e in decode_table(int(count), np.asarray(table))]
    expect = [(i, w) for i, w in enumerate(words) if w != 0][:8]
    assert int(count) == sum(1 for w in words if w)
    assert got == expect
