"""Elastic training over the multi-controller simulation: LFLR + shrink."""
import numpy as np
import pytest

from repro.core.faults import FaultSchedule, FaultSpec
from repro.launch.elastic import elastic_train


def test_fault_free_convergence():
    res = elastic_train(4, steps=30, lr=0.2)
    for r in res:
        assert r.exception is None, r.exception
        assert r.value.steps_done == 30
        assert r.value.final_loss < 1e-2


def test_soft_fault_propagates_and_all_skip():
    faults = FaultSchedule([FaultSpec(step=5, kind="nan_grad", rank=2)])
    res = elastic_train(4, steps=20, lr=0.2, faults=faults)
    for r in res:
        assert r.exception is None, r.exception
        ev = [e for e in r.value.events if e[0] == "propagated"]
        assert len(ev) == 1
        assert ev[0][2] == [2]          # every rank learned *who* failed
        assert r.value.final_loss < 1e-2  # and training still converged


def test_hard_fault_shrinks_and_survivors_finish():
    faults = FaultSchedule([FaultSpec(step=8, kind="kill", rank=1)])
    res = elastic_train(4, steps=25, lr=0.2, faults=faults)
    assert res[1].killed
    for i in (0, 2, 3):
        r = res[i]
        assert r.exception is None, r.exception
        ev = [e for e in r.value.events if e[0] == "shrink"]
        assert len(ev) == 1 and ev[0][2] == 3   # world shrank 4 → 3
        assert r.value.steps_done >= 1
        assert r.value.world_sizes[-1] == 3
        assert r.value.final_loss < 5e-2        # training recovered post-shrink
    # survivors agree on the weights (consistent restored state)
    w = [res[i].value.weights for i in (0, 2, 3)]
    np.testing.assert_allclose(w[0], w[1], rtol=1e-6)
    np.testing.assert_allclose(w[0], w[2], rtol=1e-6)


def test_two_kills_two_shrinks():
    faults = FaultSchedule([FaultSpec(step=6, kind="kill", rank=1),
                            FaultSpec(step=14, kind="kill", rank=3)])
    res = elastic_train(5, steps=20, lr=0.2, faults=faults)
    assert res[1].killed and res[3].killed
    for i in (0, 2, 4):
        r = res[i]
        assert r.exception is None, r.exception
        assert r.value.world_sizes[-1] == 3     # 5 → 4 → 3
