"""Unit tests for the phi-accrual heartbeat failure detector (DESIGN §3.9).

All under a fake clock — no sleeps, no processes: the detector's ladder
(healthy → suspect → evictable), the adaptive-vs-hard threshold split, the
SIGSTOP slow-but-alive discrimination and the latency bound are pure
functions of beat timestamps.
"""
import pytest

from repro.serve import PhiAccrualDetector

HB = 0.05
TIMEOUT = 1.0


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def make(**kw):
    clock = FakeClock()
    kw.setdefault("suspect_timeout", TIMEOUT)
    kw.setdefault("heartbeat_interval", HB)
    det = PhiAccrualDetector(clock=clock, **kw)
    return det, clock


def beat_regularly(det, clock, rank, n, interval=HB):
    for _ in range(n):
        clock.advance(interval)
        det.heartbeat(rank)


# --------------------------------------------------------------- validation
@pytest.mark.parametrize("kw", [
    dict(suspect_timeout=0.0),
    dict(suspect_timeout=-1.0),
    dict(heartbeat_interval=0.0),
    dict(heartbeat_interval=2.0),          # >= suspect_timeout
    dict(evict_factor=1.0),                # no SIGSTOP margin
    dict(evict_factor=2.5),                # breaks the 2x latency bound
    dict(phi_threshold=0.0),
])
def test_parameter_validation(kw):
    with pytest.raises(ValueError):
        make(**kw)


def test_register_remove_bookkeeping():
    det, clock = make()
    det.register(0)
    det.register(1)
    assert det.ranks() == [0, 1]
    det.remove(0)
    assert det.ranks() == [1]
    # beats from an unknown rank are ignored, never KeyError
    assert det.heartbeat(7) is False
    assert det.poll() == ([], [])


# ------------------------------------------------------- suspect/evict ladder
def test_healthy_host_is_never_suspected():
    det, clock = make()
    det.register(0)
    for _ in range(200):
        clock.advance(HB)
        det.heartbeat(0)
        newly, evictable = det.poll()
        assert not newly and not evictable
    assert not det.is_suspect(0)


def test_hard_timeout_suspects_then_evicts_within_bound():
    det, clock = make(evict_factor=1.8)
    det.register(0)
    # noisy-but-alive history: wide inter-arrival spread keeps phi low, so
    # only the hard suspect_timeout bound can fire
    for k in range(40):
        clock.advance(HB if k % 2 else 8 * HB)
        det.heartbeat(0)
    silent_from = clock.t
    # just short of the hard bound: not suspect (phi stays under threshold)
    clock.advance(0.95 * TIMEOUT)
    newly, evictable = det.poll()
    assert newly == [] and evictable == []
    # crossing it: suspect, but not yet evictable (the SIGSTOP margin)
    clock.advance(0.06 * TIMEOUT)
    newly, evictable = det.poll()
    assert newly == [0] and evictable == []
    assert det.is_suspect(0)
    # suspicion is entered once per silent stretch
    clock.advance(0.01)
    newly, _ = det.poll()
    assert newly == []
    # evictable at evict_factor x suspect_timeout — within the 2x bound
    clock.advance(1.8 * TIMEOUT - (clock.t - silent_from) + 0.01)
    newly, evictable = det.poll()
    assert evictable == [0]
    assert clock.t - silent_from <= 2 * TIMEOUT


def test_adaptive_threshold_fires_early_for_tight_beats_only():
    """The phi path: a host with a tight, regular beat history is suspected
    well before the hard timeout; a noisy host with the SAME silence is not
    (the adaptive threshold is per-host history, not a global constant)."""
    det, clock = make()
    det.register(0)    # tight: every beat exactly on the interval
    det.register(1)    # noisy: wildly irregular gaps (1 / 6 / 10 intervals)
    for k in range(120):
        clock.advance(HB)
        det.heartbeat(0)
        if k % 17 in (0, 1, 7):
            det.heartbeat(1)
    # half the hard timeout of silence: far beyond rank 0's observed spread,
    # unremarkable for rank 1
    clock.advance(0.5 * TIMEOUT)
    newly, evictable = det.poll()
    assert 0 in newly, "tight-beat host not adaptively suspected"
    assert 1 not in newly, "noisy host suspected below the hard timeout"
    assert evictable == []
    assert det.phi(0) > det.phi(1)


def test_one_late_beat_is_never_suspicious():
    """The two-interval grace floor: a single missed beat (silence just past
    one interval) must not trip the adaptive path even with a perfectly
    regular history."""
    det, clock = make()
    det.register(0)
    beat_regularly(det, clock, 0, 60)
    clock.advance(1.9 * HB)      # under the 2x heartbeat_interval floor
    newly, _ = det.poll()
    assert newly == []


# --------------------------------------------------------- SIGSTOP guard
def test_stopped_then_resumed_host_is_cleared_not_evicted():
    """A SIGSTOP'd worker resumed within suspect_timeout: suspicion is
    entered during the gap, the first post-resume beat clears it
    (heartbeat() -> True), and the host is never evictable."""
    det, clock = make(evict_factor=1.8)
    det.register(0)
    beat_regularly(det, clock, 0, 60)
    # paused for 90% of the hard bound: suspected (adaptive), never evictable
    clock.advance(0.9 * TIMEOUT)
    newly, evictable = det.poll()
    assert newly == [0] and evictable == []
    # resume: the late beat clears the suspicion and re-arms detection
    assert det.heartbeat(0) is True
    assert not det.is_suspect(0)
    newly, evictable = det.poll()
    assert newly == [] and evictable == []
    # healthy afterwards — the stale gap in the history must not wedge the
    # detector into either permanent suspicion or permanent immunity
    beat_regularly(det, clock, 0, 60)
    assert det.poll() == ([], [])
    clock.advance(2.1 * TIMEOUT)
    newly, evictable = det.poll()
    assert newly == [0] and evictable == [0]


def test_clearing_beat_rearms_eviction_clock():
    """Eviction needs a *fresh* suspect stretch after a clear: the silence
    accumulated before a resume never counts toward evict_after."""
    det, clock = make(evict_factor=1.8)
    det.register(0)
    beat_regularly(det, clock, 0, 40)
    clock.advance(0.95 * TIMEOUT)
    det.poll()
    assert det.heartbeat(0) is True      # resumed just in time
    resumed_at = clock.t
    clock.advance(1.0 * TIMEOUT)         # silent again, from scratch
    newly, evictable = det.poll()
    assert det.is_suspect(0)
    assert evictable == [], (
        "pre-resume silence leaked into the eviction clock")
    clock.advance(1.8 * TIMEOUT - (clock.t - resumed_at) + 0.01)
    _, evictable = det.poll()
    assert evictable == [0]
