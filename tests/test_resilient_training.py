"""Integration: ResilientExecutor end-to-end training with injected faults —
the paper's technique driving real (small) training on CPU."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import (
    Action,
    ErrorCode,
    ExecutorConfig,
    FaultSchedule,
    FaultSpec,
    ResilientExecutor,
)
from repro.core.recovery import RecoveryPolicy
from repro.checkpoint import Checkpointer
from repro.launch.train import build_train_setup
from repro.launch.steps import make_reset_opt_fn


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("qwen3-1.7b")
    model, step_fn, state, pipe, opt_cfg = build_train_setup(
        cfg, batch_size=2, seq_len=16, total_steps=60)
    return cfg, step_fn, state, pipe


def _executor(cfg, step_fn, tmp_path=None, **kw):
    ckpt = Checkpointer(tmp_path) if tmp_path else None
    return ResilientExecutor(
        step_fn,
        policy=RecoveryPolicy(can_shrink=False),
        config=ExecutorConfig(good_state_interval=5, checkpoint_interval=10),
        checkpointer=ckpt,
        reset_opt_fn=make_reset_opt_fn(cfg),
        **kw,
    )


def test_fault_free_training_descends(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    state2, log = ex.run(state, iter(pipe), 12)
    losses = [e for e in log.events if e.kind == "ok"]
    assert len(losses) == 12
    assert int(state2["step"]) == 12


def test_nan_grad_detected_and_skipped(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    faults = FaultSchedule([FaultSpec(step=3, kind="nan_grad")])
    state2, log = ex.run(state, iter(pipe), 8, faults=faults)
    fl = log.faults()
    assert len(fl) == 1 and fl[0].step == 3
    assert fl[0].code & int(ErrorCode.NONFINITE_GRAD)
    assert fl[0].action == Action.SKIP_BATCH.value
    # the faulty update was discarded: training continued to step count 7
    # (one step consumed by the skip)
    assert int(state2["step"]) == 7
    # and params stayed finite
    flat = jax.tree_util.tree_leaves(state2["params"])
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat
               if jnp.issubdtype(x.dtype, jnp.floating))


def test_repeated_faults_escalate_to_restore(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    faults = FaultSchedule([FaultSpec(step=s, kind="nan_loss")
                            for s in (4, 5)])
    _, log = ex.run(state, iter(pipe), 10, faults=faults)
    actions = [e.action for e in log.faults()]
    assert actions[0] == Action.SKIP_BATCH.value
    assert actions[1] == Action.RESTORE_GOOD.value     # LFLR escalation


def test_spike_loss_triggers_optimizer_reset(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    faults = FaultSchedule([FaultSpec(step=5, kind="spike_loss")])
    state2, log = ex.run(state, iter(pipe), 8, faults=faults)
    fl = log.faults()
    assert fl and fl[0].code & int(ErrorCode.DIVERGENCE)
    assert fl[0].action == Action.RESET_OPTIMIZER.value
    # lr_scale decayed (paper use case 2: solver restart with damping)
    assert float(state2["lr_scale"]) < 1.0
    # moments were reset at that point: second moment small right after
    assert int(state2["step"]) == 7


def test_bad_data_detected(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    faults = FaultSchedule([FaultSpec(step=2, kind="bad_data")])
    _, log = ex.run(state, iter(pipe), 5, faults=faults)
    fl = log.faults()
    assert fl and fl[0].code & int(ErrorCode.DATA_FAULT)


def test_rollback_from_checkpoint(tmp_path, setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn, tmp_path=tmp_path)
    # many faults in a tight window force ROLLBACK (escalation past retries)
    faults = FaultSchedule([FaultSpec(step=s, kind="nan_loss")
                            for s in (12, 13, 14, 15, 16)])
    state2, log = ex.run(state, iter(pipe), 20, faults=faults)
    actions = [e.action for e in log.faults()]
    assert Action.ROLLBACK.value in actions
    ex.checkpointer.wait()
    assert ex.checkpointer.list_steps()  # a durable checkpoint exists


def test_straggler_watchdog(setup):
    cfg, step_fn, state, pipe = setup
    ex = _executor(cfg, step_fn)
    # the watchdog fires at 3× the EMA step time; a 0.5s straggle was flaky on
    # loaded boxes where normal smoke steps crept toward the threshold — 2s
    # keeps the margin wide enough to be deterministic in practice
    faults = FaultSchedule([FaultSpec(step=6, kind="straggle", magnitude=2.0)])
    _, log = ex.run(state, iter(pipe), 9, faults=faults)
    stragglers = [e for e in log.events if e.kind == "straggler"]
    assert stragglers and stragglers[0].step == 6
