"""Roofline machinery: HLO collective parsing, HBM estimator, term maths."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    RooflineTerms,
    model_flops_for,
)
from repro.roofline.hlo import (
    _shape_bytes,
    estimate_hbm_bytes,
    parse_collectives,
)

SYNTH = """
HloModule test

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[4,2]<=[8]
  %ag = bf16[64,512]{1,0} all-gather(%p0), channel_id=2, dimensions={0}
  %rs = f32[16,256]{1,0} reduce-scatter(%ar), channel_id=3, dimensions={0}
  %cp = f32[8]{0} collective-permute(%rs), channel_id=4
  ROOT %out = f32[128,256]{1,0} add(%ar, %ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[64]") == 128
    assert _shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert _shape_bytes("f32[]") == 4


def test_parse_collectives_synthetic():
    st = parse_collectives(SYNTH)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-reduce"] == 128 * 256 * 4
    assert st.bytes_by_kind["all-gather"] == 64 * 512 * 2
    assert st.bytes_by_kind["reduce-scatter"] == 16 * 256 * 4
    assert st.bytes_by_kind["collective-permute"] == 32
    assert st.total_bytes == sum(st.bytes_by_kind.values())


def test_hbm_estimator_counts_while_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    est = estimate_hbm_bytes(co.as_text())
    # 6 trips × (read x, read w, write y) ≈ 6 × 3 × 256KB; allow fusion slack
    one_buf = 256 * 256 * 4
    assert est["total_bytes"] >= 6 * 2 * one_buf
    assert 6 in est["trip_counts"].values()


def test_roofline_terms_math():
    t = RooflineTerms(chips=256, hlo_flops_per_device=197e12,
                      hlo_bytes_per_device=819e9,
                      collective_bytes_per_device=50e9,
                      model_flops=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.model_flops_ratio == pytest.approx(1.0)
    assert t.roofline_fraction == pytest.approx(1.0)


def test_model_flops_moe_uses_active():
    cfg = get_config("qwen3-moe-30b-a3b")
    train = model_flops_for(cfg, SHAPES["train_4k"])
    expect = 6.0 * cfg.active_params_count() * 256 * 4096
    assert train == pytest.approx(expect)
    dec = model_flops_for(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2.0 * cfg.active_params_count() * 128)
