"""Unit tests for the escalating RecoveryPolicy (S3 of the fuzzer PR).

The policy is the routing table the fuzzer's coverage universe is derived
from (``repro.fuzz.coverage.action_ladder`` replays it), so its decision
matrix gets pinned here decision by decision: per-code routing, the
repeat-escalation ladder, window expiry, hard-fault shrink vs rollback, and
reset semantics.
"""
import pytest

from repro.core.errors import (
    CommCorruptedError,
    ErrorCode,
    PropagatedError,
    RankError,
)
from repro.core.recovery import Action, RecoveryPolicy


def _exc(code: ErrorCode) -> PropagatedError:
    return PropagatedError([RankError(rank=0, code=int(code))])


# ------------------------------------------------------------ per-code routing
class TestRouting:
    @pytest.mark.parametrize("code", [
        ErrorCode.NONFINITE_LOSS, ErrorCode.NONFINITE_GRAD,
        ErrorCode.OVERFLOW, ErrorCode.DATA_FAULT,
    ])
    def test_transient_soft_family_skips_then_restores(self, code):
        pol = RecoveryPolicy()
        assert pol.decide(_exc(code), 1).action is Action.SKIP_BATCH
        assert pol.decide(_exc(code), 2).action is Action.RESTORE_GOOD

    def test_divergence_resets_optimizer_with_lr_decay(self):
        pol = RecoveryPolicy(divergence_lr_decay=0.25)
        d = pol.decide(_exc(ErrorCode.DIVERGENCE), 1)
        assert d.action is Action.RESET_OPTIMIZER
        assert d.lr_scale == 0.25

    @pytest.mark.parametrize("code", [ErrorCode.STATE_FAULT,
                                      ErrorCode.PAGE_FAULT])
    def test_state_and_page_faults_restore_immediately(self, code):
        assert (RecoveryPolicy().decide(_exc(code), 1).action
                is Action.RESTORE_GOOD)

    @pytest.mark.parametrize("code", [ErrorCode.ROUTER_OVERFLOW,
                                      ErrorCode.STRAGGLER])
    def test_flow_conditions_continue(self, code):
        assert RecoveryPolicy().decide(_exc(code), 1).action is Action.CONTINUE

    def test_user_and_default_skip_batch(self):
        assert (RecoveryPolicy().decide(_exc(ErrorCode.USER), 1).action
                is Action.SKIP_BATCH)
        # NONFINITE_PARAM is outside the transient family: default route
        assert (RecoveryPolicy().decide(_exc(ErrorCode.NONFINITE_PARAM),
                                        1).action is Action.SKIP_BATCH)

    def test_combined_word_routes_by_priority(self):
        # divergence outranks the transient family in the decision order
        code = ErrorCode.DIVERGENCE | ErrorCode.NONFINITE_LOSS
        assert (RecoveryPolicy().decide(_exc(code), 1).action
                is Action.RESET_OPTIMIZER)


# -------------------------------------------------------------- escalation
class TestEscalation:
    def test_fourth_repeat_in_window_rolls_back(self):
        pol = RecoveryPolicy()     # max_soft_retries=3, escalate_window=20
        actions = [pol.decide(_exc(ErrorCode.NONFINITE_LOSS), s).action
                   for s in range(1, 6)]
        assert actions == [Action.SKIP_BATCH, Action.RESTORE_GOOD,
                           Action.RESTORE_GOOD, Action.ROLLBACK,
                           Action.ROLLBACK]

    def test_escalation_outranks_divergence(self):
        pol = RecoveryPolicy()
        for s in range(1, 4):
            pol.decide(_exc(ErrorCode.NONFINITE_LOSS), s)
        assert (pol.decide(_exc(ErrorCode.DIVERGENCE), 4).action
                is Action.ROLLBACK)

    def test_faults_outside_the_window_never_escalate(self):
        pol = RecoveryPolicy(escalate_window=10)
        for i in range(6):
            d = pol.decide(_exc(ErrorCode.NONFINITE_LOSS), 1 + i * 50)
            # each fault is the only one in its window: first-repeat routing
            assert d.action is Action.SKIP_BATCH

    def test_reset_clears_the_repeat_counter(self):
        pol = RecoveryPolicy()
        for s in range(1, 4):
            pol.decide(_exc(ErrorCode.NONFINITE_LOSS), s)
        pol.reset()
        assert (pol.decide(_exc(ErrorCode.NONFINITE_LOSS), 4).action
                is Action.SKIP_BATCH)

    def test_escalation_counts_across_codes(self):
        # the repeat counter is shared: three stragglers then one NaN → the
        # NaN is the fourth fault in the window and rolls back
        pol = RecoveryPolicy()
        for s in range(1, 4):
            pol.decide(_exc(ErrorCode.STRAGGLER), s)
        assert (pol.decide(_exc(ErrorCode.NONFINITE_LOSS), 4).action
                is Action.ROLLBACK)


# -------------------------------------------------------------- hard faults
class TestHardFaults:
    def test_comm_corrupted_shrinks_with_ulfm(self):
        assert (RecoveryPolicy(can_shrink=True)
                .decide(CommCorruptedError(), 1).action is Action.SHRINK)

    def test_comm_corrupted_rolls_back_without_ulfm(self):
        # the black-channel path cannot shrink (paper §III-C)
        assert (RecoveryPolicy(can_shrink=False)
                .decide(CommCorruptedError(), 1).action is Action.ROLLBACK)

    def test_rank_failed_word_routes_like_a_hard_fault(self):
        assert (RecoveryPolicy(can_shrink=True)
                .decide(_exc(ErrorCode.RANK_FAILED), 1).action
                is Action.SHRINK)
        assert (RecoveryPolicy(can_shrink=False)
                .decide(_exc(ErrorCode.RANK_FAILED), 1).action
                is Action.ROLLBACK)

    def test_hard_faults_never_consume_the_soft_budget(self):
        pol = RecoveryPolicy()
        for s in range(1, 10):
            pol.decide(CommCorruptedError(), s)
        # soft counter untouched: next soft fault is a first repeat
        assert (pol.decide(_exc(ErrorCode.NONFINITE_LOSS), 10).action
                is Action.SKIP_BATCH)

    def test_unhandled_exception_aborts(self):
        assert (RecoveryPolicy().decide(RuntimeError("?"), 1).action
                is Action.ABORT)
