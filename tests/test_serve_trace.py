"""Fault-causality tracing (repro.obs): span causality per request, fault
events bit-matching the device error-word histories, kill -> shrink ->
re-route chains in a ServeGroup trace, the no-op tracer's bit-exactness, and
the EventLog/metrics export satellites (real timestamps, merged summaries)."""
import jax
import pytest

from repro.configs import smoke_config
from repro.core.errors import ErrorCode
from repro.core.faults import FaultSchedule, FaultSpec
from repro.core.resilient import Event, EventLog
from repro.models import build_model
from repro.obs import (
    ENGINE_TID,
    NULL_TRACER,
    NullTracer,
    Tracer,
    event_log_to_events,
    fault_report,
    group_chains,
    merge_traces,
    request_timelines,
    validate,
)
from repro.serve import (
    OK,
    EngineConfig,
    Replica,
    Request,
    ServeGroup,
    ServeMetrics,
)

MAX_LEN = 64


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("recurrentgemma-2b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _replica(env, tracer, **kw):
    cfg, params = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", MAX_LEN)
    conf.setdefault("window", 4)
    conf.setdefault("max_request_retries", 6)
    return Replica(cfg, params=params, config=EngineConfig(**conf),
                   tracer=tracer, **kw)


def _requests(n, max_new=10):
    return [Request(id=i, prompt=(10 + i, 20 + i, 30 + i),
                    max_new_tokens=max_new) for i in range(n)]


def _serve(rep, reqs, inject_at=None):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps, injected = {}, 0, 0
    while not rep.idle():
        if inject_at is not None and steps >= inject_at and not injected:
            eligible = [i for i in rep.sched.active_slots()
                        if rep.sched.slots[i].pending is None]
            if eligible and rep.inject_state_fault(eligible[0]) is not None:
                injected += 1
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 1000
    if inject_at is not None:
        assert injected == 1, "injection never landed"
    return out


def _by_name(events, name):
    return [e for e in events if e.get("name") == name]


def _args(ev):
    return ev.get("args") or {}


# -------------------------------------------------------- span causality
def test_clean_run_causal_timeline_per_request(env):
    """Every request's life is one ordered causal chain: submit -> slot
    assignment -> (chunks) -> decode spans -> first_token -> exactly one
    terminal request span containing all of it."""
    tr = Tracer()
    out = _serve(_replica(env, tr), _requests(3))
    assert all(r.status == OK for r in out.values())
    trace = merge_traces(tr)
    assert validate(trace) == []
    timelines = request_timelines(trace)
    assert sorted(timelines) == [0, 1, 2]
    for tid, evs in timelines.items():
        names = [e["name"] for e in evs]
        assert names[0] == "submit"
        assert names.count("request") == 1
        assert "slot_assign" in names
        assert "first_token" in names
        assert "decode" in names
        # wall-ordered causal chain
        assert names.index("submit") < names.index("slot_assign")
        assert names.index("slot_assign") < names.index("first_token")
        term = _by_name(evs, "request")[0]
        assert _args(term)["status"] == OK
        assert _args(term)["tokens"] == len(out[tid].tokens)
    # anonymous engine spans ride the engine lane, not a slot lane
    wins = _by_name(trace["traceEvents"], "window")
    assert wins and all(w["tid"] == ENGINE_TID for w in wins)


def test_overlap_chunks_traced(env):
    """Overlapped admission shows up as chunk events attributed to the
    request, and the chunk count matches the metrics counter."""
    tr = Tracer()
    rep = _replica(env, tr, num_slots=2)
    reqs = [Request(id=i, prompt=tuple(3 + i + j for j in range(9)),
                    max_new_tokens=8) for i in range(4)]
    out = _serve(rep, reqs)
    assert all(r.status == OK for r in out.values())
    chunks = _by_name(tr.events(), "chunk")
    assert len(chunks) == rep.metrics.prefill_chunks
    assert sum(_args(c)["tokens"] for c in chunks) == \
        rep.metrics.prefill_chunk_tokens
    assert all(_args(c)["trace_id"] is not None for c in chunks)


# ------------------------------------------------- fault span bit-matching
def test_window_fault_events_bitmatch_error_words(env):
    """The fault events carry, per attributed slot, the exact error word the
    ``(K, slots)`` history OR-fold read back: their OR equals the combined
    word the recovery policy saw (``FaultRecord.code``), and the causal chain
    fault -> recovery -> recovered closes."""
    tr = Tracer()
    rep = _replica(env, tr)
    # long generations: the faulted lane must still be mid-flight when the
    # deferred detection surfaces, so a recovery lane actually opens
    out = _serve(rep, _requests(3, max_new=24), inject_at=3)
    assert all(r.status == OK for r in out.values())
    records = [f for f in rep.metrics.faults if f.action != "prefill_retry"]
    assert records
    trace = merge_traces(tr)
    assert validate(trace) == []
    fault_evs = [e for e in trace["traceEvents"] if e["cat"] == "fault"]
    assert fault_evs
    rec = records[0]
    batch = [e for e in fault_evs
             if _args(e).get("action") == rec.action
             and _args(e)["slot"] in rec.slots]
    assert {(_args(e)["slot"]) for e in batch} == set(rec.slots)
    word = 0
    for e in batch:
        word |= _args(e)["code"]
        # class decomposition matches the word bit-for-bit
        assert set(_args(e)["code_names"]) == {
            c.name for c in ErrorCode(_args(e)["code"]).classes()}
        assert _args(e)["window"] is not None
    assert word == rec.code
    # the fault resolves into a completed recovery lane
    report = fault_report(trace)
    assert report and all(fr.resolved for fr in report)
    recovered = [fr for fr in report if fr.recovery is not None
                 and _args(fr.recovery)["outcome"] == "recovered"]
    assert recovered
    assert all(fr.recovery_s > 0 for fr in recovered)


def test_stepwise_fault_events_bitmatch_enumeration(env):
    """The stepwise engine has no window history: its fault events carry the
    per-(slot, code) pairs of the paper's enumeration, OR-matching the
    combined word."""
    tr = Tracer()
    rep = _replica(env, tr, window=0)
    out = _serve(rep, _requests(3), inject_at=3)
    assert all(r.status == OK for r in out.values())
    records = [f for f in rep.metrics.faults if f.action != "prefill_retry"]
    assert records
    trace = merge_traces(tr)
    assert validate(trace) == []
    fault_evs = [e for e in trace["traceEvents"] if e["cat"] == "fault"
                 and _args(e).get("action") == records[0].action]
    assert fault_evs
    word = 0
    for e in fault_evs:
        word |= _args(e)["code"]
        assert _args(e)["step"] == records[0].step
    assert word == records[0].code


def test_paged_page_events_and_eviction_requeue():
    """Paged-KV pressure: allocations, frees and evictions all leave page
    events; an evicted lane's requeue -> re-assignment stays on the same
    trace id, and the evicted request still finishes OK."""
    cfg = smoke_config("qwen3-1.7b")
    tr = Tracer()
    rep = Replica(cfg, config=EngineConfig(num_slots=4, max_len=64, window=4,
                                           overlap=True,
                                           max_request_retries=6, paged=True,
                                           page_size=16, page_budget=8),
                  tracer=tr)
    reqs = [Request(id=i, prompt=tuple(3 + i + j for j in range(8)),
                    max_new_tokens=12) for i in range(6)]
    out = _serve(rep, reqs)
    assert all(r.status == OK for r in out.values())
    m = rep.metrics
    evs = tr.events()
    assert len(_by_name(evs, "page_evict")) == m.page_evictions
    assert sum(_args(e)["pages"] for e in _by_name(evs, "page_alloc")) == \
        m.pages_allocated
    assert sum(_args(e)["pages"] for e in _by_name(evs, "page_free")) == \
        m.pages_freed
    assert validate(merge_traces(tr)) == []
    if m.page_evictions:
        ev = _by_name(evs, "page_evict")[0]
        tid = _args(ev)["trace_id"]
        names = [e["name"] for e in evs if _args(e).get("trace_id") == tid]
        # evicted -> requeued -> re-assigned a slot -> still answered
        i = names.index("page_evict")
        assert "requeue" in names[i:]
        assert "slot_assign" in names[names.index("requeue", i):]


def test_spec_draft_events_and_fault_word_strips_reject_bits():
    """Speculative windows: accepted/drafted counters trace per window; a
    real fault's event word may carry DRAFT_REJECT attribution bits, but
    masked by them it bit-matches the fault-raising combined word."""
    cfg = smoke_config("qwen3-1.7b")
    tr = Tracer()
    rep = Replica(cfg, config=EngineConfig(num_slots=2, max_len=64, window=4,
                                           overlap=True,
                                           max_request_retries=6,
                                           speculate=True, draft_len=2,
                                           draft_layers=1),
                  seed=0, tracer=tr)
    reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(6)),
                    max_new_tokens=10) for i in range(3)]
    out = _serve(rep, reqs, inject_at=3)
    assert all(r.status == OK for r in out.values())
    spec_evs = _by_name(tr.events(), "speculate")
    assert spec_evs
    assert sum(_args(e)["drafted"] for e in spec_evs) == \
        rep.metrics.draft_tokens
    assert sum(_args(e)["accepted"] for e in spec_evs) == \
        rep.metrics.accepted_draft_tokens
    records = [f for f in rep.metrics.faults if f.action != "prefill_retry"]
    assert records
    rec = records[0]
    fault_evs = [e for e in tr.events() if e["cat"] == "fault"
                 and _args(e).get("action") == rec.action]
    assert fault_evs
    word = 0
    for e in fault_evs:
        word |= _args(e)["code"]
    assert word & ~int(ErrorCode.DRAFT_REJECT) == rec.code
    assert validate(merge_traces(tr)) == []


# ---------------------------------------------------- group kill chain
def test_group_kill_shrink_reroute_one_connected_trace():
    """A replica kill produces one connected cross-replica chain in the
    merged trace: kill -> ulfm_shrink on every survivor -> reroute per moved
    request -> the re-routed requests' terminal spans on their new owner."""
    cfg = smoke_config("recurrentgemma-2b")
    group = ServeGroup(cfg, 3, config=EngineConfig(num_slots=2, max_len=48,
                                                   window=4, trace=True))
    reqs = [Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=5)
            for i in range(9)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=1)]))
    assert all(r.ok for r in res.responses.values())
    assert sorted(res.tracers) == [0, 1, 2]
    trace = res.trace()
    assert validate(trace) == []
    chains = group_chains(trace)
    assert len(chains) == 1
    chain = chains[0]
    assert chain["dead_rank"] == 1
    # both survivors observed the shrink; nobody lists the dead rank
    assert {s["pid"] for s in chain["shrinks"]} == {0, 2}
    assert all(1 not in _args(s)["survivors"] for s in chain["shrinks"])
    # every re-route names the dead rank as source, a survivor as target,
    # and the moved request reached a terminal span on its new owner
    routed = {_args(r)["request"] for r in chain["reroutes"]}
    assert routed == set(res.rerouted)
    for r in chain["reroutes"]:
        assert _args(r)["from_rank"] == 1
        assert _args(r)["to_rank"] in (0, 2)
        term = chain["terminals"][_args(r)["trace_id"]]
        assert term is not None and _args(term)["status"] == OK
        assert term["pid"] == _args(r)["to_rank"]
    # the dead rank's own spans (the cause half) survive in the merged trace
    assert any(e["pid"] == 1 and e["name"] == "replica_kill"
               for e in trace["traceEvents"])
    # satellite: the fleet-level merged summary
    s = res.summary()
    assert s["replicas"] == 3 and s["survivors"] == 2
    assert s["rerouted"] == len(res.rerouted)
    assert s["requests"] == 9 and s["statuses"] == {OK: 9}


# ----------------------------------------------- no-op tracer / sampling
def test_null_tracer_bit_exact_and_recordless(env):
    """The default (no tracer) serve path records zero events and emits the
    bit-identical token stream a traced replica does."""
    plain = _replica(env, None)
    assert isinstance(plain.trace, NullTracer) and not plain.trace.enabled
    base = _serve(plain, _requests(3), inject_at=3)
    assert plain.trace.num_events == 0
    assert NULL_TRACER.num_events == 0
    tr = Tracer()
    got = _serve(_replica(env, tr), _requests(3), inject_at=3)
    assert sorted(got) == sorted(base)
    for i in base:
        assert got[i].tokens == base[i].tokens, i
    assert tr.num_events > 0


def test_sampling_is_deterministic_and_engine_spans_survive(env):
    """sample=0 keeps engine-scoped spans (windows) but no request-scoped
    ones; the sampling decision is a pure hash of the request id."""
    tr = Tracer(sample=0.0)
    out = _serve(_replica(env, tr), _requests(2))
    assert all(r.status == OK for r in out.values())
    evs = tr.events()
    assert _by_name(evs, "window")          # engine spans always kept
    assert not _by_name(evs, "submit")
    assert all(_args(e).get("trace_id") is None for e in evs)
    assert all(r.trace_id is None for r in out.values())
    half = Tracer(sample=0.5)
    assert [half.sampled(i) for i in range(64)] == \
        [Tracer(sample=0.5).sampled(i) for i in range(64)]
    kept = sum(half.sampled(i) for i in range(1024))
    assert 0 < kept < 1024
    with pytest.raises(ValueError):
        Tracer(sample=1.5)


# ------------------------------------------- EventLog export satellites
def _clock(values):
    it = iter(values)
    last = [0.0]

    def tick():
        for v in it:
            last[0] = v
            return v
        return last[0]

    return tick


def test_to_event_log_emits_real_timestamps_in_wall_order():
    """Satellite 1: the serving EventLog export stamps every event with its
    real wall clock and emits the merged stream in wall order, so a training
    + serving post-mortem interleaves causally."""
    m = ServeMetrics(clock=_clock([10.0, 11.0, 12.0, 13.0]))
    from repro.serve.queue import Response
    m.record_response(Response(id=0, status=OK, tokens=(1,), latency_s=2.0))
    m.record_fault(step=3, code=int(ErrorCode.STATE_FAULT), action="skip",
                   slots=(0,))
    m.record_response(Response(id=1, status=OK, tokens=(2,), latency_s=1.0))
    log = m.to_event_log()
    stamps = [e.t for e in log.events]
    assert stamps == sorted(stamps) and all(t > 0 for t in stamps)
    kinds = [(e.kind, e.t) for e in log.events]
    assert kinds == [("ok", 10.0), ("fault", 11.0), ("ok", 12.0)]
    fault = log.faults()[0]
    assert fault.code == int(ErrorCode.STATE_FAULT) and fault.step == 3
    # responses are re-indexed by completion order
    assert [e.step for e in log.events if e.kind == "ok"] == [0, 1]
    # and the trace_event conversion keeps the ordering (spans start early)
    evs = event_log_to_events(log)
    assert [e["ts"] for e in evs] == [8.0e6, 11.0e6, 11.0e6]
    assert evs[0]["ph"] == "X" and evs[0]["dur"] == 2.0e6
    assert evs[1]["ph"] == "i"


def test_training_event_log_merges_with_serving_trace():
    """One post-mortem reads both worlds: executor EventLog events convert to
    the same trace_event schema and interleave with serving spans by ts."""
    log = EventLog()
    log.add(Event(step=0, kind="ok", duration_s=0.5, t=10.5))
    log.add(Event(step=1, kind="fault", code=int(ErrorCode.NONFINITE_LOSS),
                  action="restore_good", t=11.0))
    train = event_log_to_events(log, pid=7)
    assert all(e["cat"] == "train" and e["pid"] == 7 for e in train)
    tr = Tracer(clock=_clock([10.2]))
    tr.instant("submit", "request", trace_id=0)
    merged = merge_traces(tr)
    merged["traceEvents"].extend(train)
    from repro.obs import events_of
    names = [e["name"] for e in events_of(merged)]
    assert names == ["ok", "submit", "fault"]


def test_metrics_merged_pools_populations():
    """Satellite 2: ServeMetrics.merged sums counters, maxes peaks, pools
    responses so percentiles cover the fleet's population."""
    from repro.serve.queue import Response
    a = ServeMetrics(clock=_clock([1.0, 2.0]))
    b = ServeMetrics(clock=_clock([4.0, 5.0]))
    a.record_window(4, 1, 4)
    b.record_window(6, 0, 4)
    a.record_pages(allocated=3, in_use=3)
    b.record_pages(allocated=2, in_use=5)
    a.record_response(Response(id=0, status=OK, tokens=(1,), latency_s=1.0))
    b.record_response(Response(id=1, status=OK, tokens=(2,), latency_s=3.0))
    m = ServeMetrics.merged([a, b])
    assert m.decode_tokens == 10 and m.windows == 2
    assert m.pages_allocated == 5 and m.peak_pages_in_use == 5
    assert len(m.responses) == 2
    assert m.latency_percentiles()["p99"] > 2.0     # pooled, not averaged
    # fleet wall window spans min t0 .. max t_last across replicas
    assert m.tokens_per_s() == pytest.approx(10 / 3.0)
